//! The HTHC epoch loop (paper §III, Fig. 1).
//!
//! Per epoch, the leader:
//!
//! 1. refreshes iterate-dependent model constants (`epoch_refresh`),
//! 2. snapshots `(v, alpha)` and materializes `w` for task A,
//! 3. selects the next batch from the (stale) gap memory — first epoch
//!    is uniform random, as all gaps start unknown,
//! 4. swaps the batch columns into task B's fast-tier working set,
//! 5. releases tasks A and B **concurrently** on their disjoint pools,
//! 6. when B finishes its batch, raises A's stop flag, collects
//!    staleness statistics, evaluates convergence, and loops.
//!
//! Task A's bulk gap computation can optionally be routed through the
//! AOT-compiled JAX/Pallas artifacts (the [`GapBackend`] hook, fulfilled
//! by `crate::runtime`); python is never involved at run time.

use super::config::{host_threads, HthcConfig};
use super::gap_memory::GapMemory;
use super::perf_model::{tile_cols_for, AutoTuner, EpochMeasurement};
use super::selection::Selection;
use super::shared_vec::SharedVector;
use super::working_set::WorkingSet;
use super::{task_a, task_b};
use crate::data::Matrix;
use crate::glm;
use crate::memory::Tier;
use crate::metrics::{ConvergenceTrace, PhaseTimes, StalenessHistogram};
use crate::sched::TileScheduler;
use crate::solver::{keys, notify_epoch, EpochEvent, Extras, FitReport, Problem};
use crate::sync::{AtomicBool, Ordering};
use crate::threadpool::WorkerPool;
use crate::util::{Rng, Timer};

/// Offload hook for task A's batched gap evaluation (PJRT runtime).
pub trait GapBackend: Sync {
    /// Compute `z = gap(<w, d_j>, alpha_j)` for a coordinate block.
    /// Returns None if this block cannot be offloaded (e.g. shape
    /// mismatch with every compiled artifact) — caller falls back to
    /// the native path.
    fn batch_gaps(
        &self,
        data: &Matrix,
        coords: &[usize],
        w: &[f32],
        alpha: &[f32],
        kind: crate::glm::ModelKind,
    ) -> Option<Vec<f32>>;

    /// Preferred coordinate-block size (the artifact's n-tile).
    fn block_len(&self) -> usize;
}

/// The solver: owns the two pinned pools for the lifetime of a run
/// (paper §IV-B: constant thread pools, no churn across epochs).
/// Entered through [`crate::solver::Hthc`] / [`crate::solver::Trainer`];
/// the one-release `train`/`train_with_backend` shims are gone.
pub struct HthcSolver {
    pub config: HthcConfig,
    pool_a: WorkerPool,
    pool_b: WorkerPool,
}

impl HthcSolver {
    pub fn new(config: HthcConfig) -> Self {
        config.validate();
        let pool_a = WorkerPool::with_name(config.t_a, "hthc-a");
        let pool_b = WorkerPool::with_name(config.t_b * config.v_b, "hthc-b");
        HthcSolver { config, pool_a, pool_b }
    }

    /// The HTHC engine loop over a [`Problem`] (entered via
    /// [`crate::solver::Hthc`]).  `problem.cfg` is expected to match
    /// `self.config` — the pools were sized from it.
    pub(crate) fn fit_problem(
        &mut self,
        problem: &mut Problem<'_>,
        backend: Option<&dyn GapBackend>,
    ) -> FitReport {
        // `&mut self` because autotuning may re-size the pools mid-run;
        // cfg is cloned so the borrow does not pin the whole solver.
        let cfg = self.config.clone();
        let data = problem.data.matrix();
        let y = problem.data.targets();
        // bulk matrix reads are charged against the dataset's recorded
        // placement (DRAM unless the builder placed it elsewhere)
        let home = problem.data.placement();
        let sim = problem.sim;
        let mut on_epoch = problem.on_epoch.take();
        let (alpha0, v0) = problem.initial_state();
        let model = &mut *problem.model;
        let (d, n) = (data.n_rows(), data.n_cols());
        let mut m_batch = cfg.batch_size(n);
        // headroom for the adaptive controller / autotuner to grow m
        let m_slots = if cfg.adaptive_r_tilde.is_some() || cfg.autotune {
            (m_batch * 4).clamp(m_batch, n)
        } else {
            m_batch
        };

        let v = SharedVector::from_slice(&v0, cfg.lock_chunk);
        let alpha = SharedVector::from_slice(&alpha0, usize::MAX >> 1);
        let gaps = GapMemory::new(n);
        let mut ws = WorkingSet::new(data, m_slots);
        let mut rng = Rng::new(cfg.seed);
        let mut trace = ConvergenceTrace::new("hthc");
        let timer = Timer::start();

        let mut total_a = 0u64;
        let mut total_b = 0u64;
        let mut total_zero = 0u64;
        let mut frac_sum = 0.0f64;
        let mut converged = false;
        let mut epochs = 0usize;
        let mut phases = PhaseTimes::default();

        // Run-split state the autotuner may revise mid-run; the pools
        // and the task-A scheduler always reflect it.  One shard per A
        // worker; tile granularity targets ~64 claims per shard.
        let (mut t_b, mut v_b) = (cfg.t_b, cfg.v_b);
        let t_a0 = self.pool_a.len().max(1);
        let mut sched_a = TileScheduler::new(n, t_a0, tile_cols_for(n, t_a0));
        let mut tuner = if cfg.autotune {
            Some(AutoTuner::new(t_a0, t_b, v_b, cfg.autotune_warmup))
        } else {
            None
        };
        let thread_budget = host_threads().unwrap_or_else(|| cfg.total_threads());

        for epoch in 1..=cfg.max_epochs {
            epochs = epoch;
            // (1) refresh model constants from the current iterate
            let tp = Timer::start();
            let alpha_snap = alpha.snapshot();
            model.epoch_refresh(&alpha_snap);
            let kind = model.kind();

            // (2) snapshot w for task A
            let v_snap = v.snapshot();
            let mut w_snap = vec![0.0f32; d];
            crate::kernels::map2_into(&mut w_snap, &v_snap, y, |vj, yj| kind.w_of(vj, yj));
            phases.snapshot_secs += tp.secs();

            // (3) batch selection (first epoch: random — z still unknown)
            let tp = Timer::start();
            let sel = if epoch == 1 { Selection::Random } else { cfg.selection };
            let batch = sel.select(&gaps.values(), m_batch, &mut rng);
            phases.select_secs += tp.secs();

            // (4) working-set swap (fast tier)
            let tp = Timer::start();
            ws.swap_in(data, &batch, sim, home);
            phases.swap_secs += tp.secs();

            // (5) release A and B concurrently
            let tp = Timer::start();
            gaps.reset_epoch_counter();
            let stop = AtomicBool::new(false);
            let snap = task_a::ASnapshot { w: &w_snap, alpha: &alpha_snap, kind, epoch: epoch as u32 };
            let seed_a = cfg.seed ^ (epoch as u64) << 20;
            // tier counters bracket exactly the concurrent phase, so the
            // autotuner sees the run traffic without swap/eval noise
            let slow0 = sim.stats(Tier::Slow);
            let fast0 = sim.stats(Tier::Fast);
            let (b_stats, a_updates) = std::thread::scope(|s| {
                let sched = &sched_a;
                let a_handle = s.spawn(|| match backend {
                    None => task_a::run_epoch(
                        &self.pool_a, data, &snap, &gaps, &stop, sim, home, sched,
                    ),
                    Some(be) => run_a_offload(be, data, &snap, &gaps, &stop, &mut Rng::new(seed_a)),
                });
                let items = task_b::WorkItem::from_batch(&batch);
                let b_stats = task_b::run_epoch(
                    &self.pool_b, &ws, &items, &v, y, &alpha, kind,
                    t_b, v_b, sim,
                );
                stop.store(true, Ordering::Relaxed);
                // PANIC-OK: propagating a worker panic is the intended
                // failure mode — the epoch result would be garbage.
                (b_stats, a_handle.join().expect("task A panicked"))
            });
            let run_secs = tp.secs();
            phases.run_secs += run_secs;

            // Autotune: observe the measured phase, and once warm,
            // solve the §IV-F program over the *measured* costs and
            // re-shape pools / scheduler / batch to the recommendation.
            if let Some(t) = tuner.as_mut() {
                let slow1 = sim.stats(Tier::Slow);
                let fast1 = sim.stats(Tier::Fast);
                t.observe(EpochMeasurement {
                    run_secs,
                    a_updates,
                    b_updates: b_stats.updates,
                    slow_read_bytes: slow1.read_bytes.saturating_sub(slow0.read_bytes),
                    fast_read_bytes: fast1.read_bytes.saturating_sub(fast0.read_bytes),
                });
            }
            if tuner.as_ref().is_some_and(|t| t.ready()) {
                // PANIC-OK: readiness was checked on the line above.
                let t = tuner.take().expect("readiness was just checked");
                let r_tilde = cfg.adaptive_r_tilde.unwrap_or(0.15);
                let fracs = [0.02, 0.05, 0.08, 0.1, 0.15, 0.25];
                if let Some(rec) = t.recommend(sim, n, r_tilde, &fracs, thread_budget) {
                    if self.pool_a.len() != rec.t_a {
                        self.pool_a = WorkerPool::with_name(rec.t_a, "hthc-a");
                    }
                    if self.pool_b.len() != rec.t_b * rec.v_b {
                        self.pool_b = WorkerPool::with_name(rec.t_b * rec.v_b, "hthc-b");
                    }
                    (t_b, v_b) = (rec.t_b, rec.v_b);
                    sched_a = TileScheduler::new(n, rec.t_a, rec.tile_cols);
                    m_batch = rec.m.clamp(1, m_slots);
                }
            }

            // (6) bookkeeping + convergence.  The refresh fraction is
            // read BEFORE B's write-back so it measures task A only.
            let (_, frac) = gaps.refresh_stats(epoch as u32);
            frac_sum += frac;

            // B write-back: an exact coordinate step zeroes that
            // coordinate's own gap — overwrite its stale z so greedy
            // selection moves on (see GapMemory::mark_processed).
            for &j in &batch {
                gaps.mark_processed(j, 0.0, epoch as u32);
            }

            // online §IV-F balance controller
            if let Some(r_tilde) = cfg.adaptive_r_tilde {
                m_batch = adapt_batch(m_batch, frac, r_tilde, m_slots);
            }
            total_a += a_updates;
            total_b += b_stats.updates;
            total_zero += b_stats.zero_deltas;

            if epoch % cfg.eval_every == 0 || epoch == cfg.max_epochs {
                let tp = Timer::start();
                let a_now: Vec<f32> = alpha.snapshot();
                // re-anchor v = D alpha exactly: incremental fp32
                // maintenance drifts after many axpys and floors the
                // measurable gap (same O(nd) cost as the eval itself)
                let v_now = data.matvec_alpha(&a_now);
                v.store_all(&v_now);
                let obj = model.objective(&v_now, y, &a_now);
                let gap = glm::total_gap(model, data.as_block_ops(), &v_now, y, &a_now);
                trace.push(timer.secs(), epoch, obj, gap);
                phases.eval_secs += tp.secs();
                let stop_requested = notify_epoch(
                    &mut on_epoch,
                    &EpochEvent {
                        solver: "hthc",
                        epoch,
                        wall_secs: timer.secs(),
                        objective: obj,
                        gap,
                        v: &v_now,
                        alpha: &a_now,
                    },
                );
                if stop_requested || gap <= cfg.gap_tol {
                    converged = true;
                    break;
                }
            }
            if timer.secs() > cfg.timeout_secs {
                break;
            }
        }

        let mut extras = Extras::default();
        extras.set_f64(keys::REFRESH_FRAC, frac_sum / epochs.max(1) as f64);
        extras.set_u64(keys::A_UPDATES, total_a);
        extras.set_u64(keys::B_UPDATES, total_b);
        extras.set_u64(keys::B_ZERO_DELTAS, total_zero);
        if cfg.autotune {
            // the split actually in effect at the end of the run (the
            // recommendation once applied, else the starting config)
            extras.set_u64(keys::AUTOTUNE_T_A, self.pool_a.len() as u64);
            extras.set_u64(keys::AUTOTUNE_T_B, t_b as u64);
            extras.set_u64(keys::AUTOTUNE_V_B, v_b as u64);
            extras.set_u64(keys::AUTOTUNE_M, m_batch as u64);
            extras.set_u64(keys::AUTOTUNE_TILE_COLS, sched_a.tile_cols() as u64);
        }
        FitReport {
            solver: "hthc",
            alpha: alpha.snapshot(),
            v: v.snapshot(),
            trace,
            epochs,
            converged,
            wall_secs: timer.secs(),
            phase_times: phases,
            staleness: StalenessHistogram::from_ages(&gaps.staleness(epochs as u32)),
            extras,
        }
    }
}

/// The online §IV-F balance law: if A refreshed less than `r_tilde` of
/// the gap memory, lengthen the epoch (a bigger batch gives A more
/// time); if it comfortably overshot, shrink toward faster epochs.
/// Multiplicative-increase / multiplicative-decrease with a dead band
/// `[r_tilde, 2 r_tilde]` to avoid oscillation.
pub fn adapt_batch(m: usize, frac: f64, r_tilde: f64, m_slots: usize) -> usize {
    if frac < r_tilde {
        ((m as f64 * 1.25) as usize).max(m + 1).min(m_slots)
    } else if frac > 2.0 * r_tilde {
        ((m as f64 * 0.8) as usize).max(1)
    } else {
        m
    }
}

/// Task A via the PJRT backend: stream random coordinate blocks through
/// the compiled gap artifact until stopped.
fn run_a_offload(
    backend: &dyn GapBackend,
    data: &Matrix,
    snap: &task_a::ASnapshot<'_>,
    gaps: &GapMemory,
    stop: &AtomicBool,
    rng: &mut Rng,
) -> u64 {
    let n = data.n_cols();
    let block = backend.block_len().max(1);
    let mut updates = 0u64;
    // SPIN-OK: work loop, not a spin — every iteration performs a full
    // block of gap computations; the flag only bounds the epoch.
    while !stop.load(Ordering::Relaxed) {
        let start = rng.below(n);
        let coords: Vec<usize> = (0..block.min(n)).map(|k| (start + k) % n).collect();
        match backend.batch_gaps(data, &coords, snap.w, snap.alpha, snap.kind) {
            Some(z) => {
                for (&j, &zj) in coords.iter().zip(&z) {
                    gaps.update(j, zj, snap.epoch);
                }
                updates += coords.len() as u64;
            }
            None => {
                // fall back to native for this block
                let ops = data.as_ops();
                for &j in &coords {
                    let u = ops.dot(j, snap.w);
                    gaps.update(j, snap.kind.gap(u, snap.alpha[j]), snap.epoch);
                }
                updates += coords.len() as u64;
            }
        }
    }
    updates
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, DatasetKind, Family};
    use crate::glm::{GlmModel, Lasso, SvmDual};
    use crate::memory::TierSim;
    use crate::solver::{FitReport, Trainer};

    fn generate(kind: DatasetKind, family: Family, scale: f64, seed: u64) -> Dataset {
        Dataset::generated(kind, family, scale, seed)
    }

    /// Relative convergence target: fp32 accumulation cannot reach
    /// absolute 1e-6 on objectives of O(1000); the paper's thresholds
    /// are likewise relative to each problem's scale.
    fn rel_tol(model: &dyn GlmModel, g: &Dataset, rel: f64) -> f64 {
        let obj0 = model.objective(&vec![0.0; g.d()], g.targets(), &vec![0.0; g.n()]);
        rel * obj0.abs().max(1.0)
    }

    /// Run the HTHC engine through the Trainer facade (the only entry
    /// point since the deprecated `train` shims were removed).
    fn fit(cfg: HthcConfig, model: &mut dyn GlmModel, g: &Dataset) -> FitReport {
        let sim = TierSim::default();
        Trainer::new().config(cfg).fit_with(model, g, &sim)
    }

    fn cfg(t_a: usize, t_b: usize, v_b: usize, frac: f64, gap_tol: f64) -> HthcConfig {
        HthcConfig {
            t_a,
            t_b,
            v_b,
            batch_frac: frac,
            gap_tol,
            // tiny uniform-importance problems can't exploit selection,
            // so a small batch needs proportionally more epochs (an
            // epoch is batch_frac of a sweep, and this conditioning
            // needs ~600 sweeps for small gaps) — these are correctness
            // tests, not the Fig. 5 speed comparison.
            max_epochs: 4000,
            timeout_secs: 30.0,
            eval_every: 2,
            ..Default::default()
        }
    }

    #[test]
    fn lasso_converges_on_dense_tiny() {
        let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 111);
        let mut model = Lasso::new(0.5);
        let tol = rel_tol(&model, &g, 1e-4);
        let res = fit(cfg(2, 2, 1, 0.25, tol), &mut model, &g);
        assert!(res.converged, "{}", res.summary());
        // v consistent with alpha at the end (locked updates lost nothing)
        let v2 = match g.matrix() {
            Matrix::Dense(m) => m.matvec_alpha(&res.alpha),
            _ => unreachable!(),
        };
        for (a, b) in res.v.iter().zip(&v2) {
            assert!((a - b).abs() < 1e-2 * b.abs().max(1.0));
        }
        assert!(res.refresh_frac() > 0.0);
    }

    #[test]
    fn svm_converges_on_classification_tiny() {
        let g = generate(DatasetKind::Tiny, Family::Classification, 1.0, 112);
        let n = g.n();
        let mut model = SvmDual::new(1e-3, n);
        let res = fit(cfg(2, 2, 2, 0.3, 1e-5), &mut model, &g);
        assert!(
            res.trace.final_gap().unwrap() < 1e-3,
            "{}", res.summary()
        );
        let acc = crate::serve::predict::accuracy(g.as_block_ops(), &res.v);
        assert!(acc > 0.9, "accuracy {acc}");
        // box respected
        assert!(res.alpha.iter().all(|&a| (-1e-6..=1.0 + 1e-6).contains(&a)));
    }

    #[test]
    fn sparse_dataset_trains() {
        let g = generate(DatasetKind::News20Like, Family::Regression, 0.04, 113);
        let mut model = Lasso::new(0.05);
        let tol = rel_tol(&model, &g, 1e-4);
        let res = fit(cfg(2, 2, 1, 0.1, tol), &mut model, &g);
        let first = res.trace.points.first().unwrap().objective;
        let last = res.trace.final_objective().unwrap();
        assert!(last < first, "objective must decrease: {first} -> {last}");
    }

    #[test]
    fn gap_selection_converges_in_fewer_epochs_than_random() {
        // The paper's core claim, in miniature: with a small batch,
        // duality-gap selection needs fewer epochs than random.
        let g = generate(DatasetKind::Tiny, Family::Regression, 2.0, 114);
        let tol = rel_tol(&Lasso::new(0.3), &g, 1e-4);
        let run = |sel: Selection| {
            let mut model = Lasso::new(0.3);
            let r = fit(
                HthcConfig {
                    t_a: 2,
                    t_b: 1,
                    v_b: 1,
                    batch_frac: 0.1,
                    selection: sel,
                    gap_tol: tol,
                    max_epochs: 2500,
                    eval_every: 1,
                    timeout_secs: 60.0,
                    ..Default::default()
                },
                &mut model,
                &g,
            );
            assert!(r.converged, "{} {}", sel.name(), r.summary());
            r.epochs
        };
        let greedy = run(Selection::DualityGap);
        let random = run(Selection::Random);
        assert!(
            greedy as f64 <= random as f64 * 0.9,
            "gap selection {greedy} epochs vs random {random}"
        );
    }

    #[test]
    fn adapt_batch_law() {
        // below target: grow (and always make progress), capped by slots
        assert_eq!(adapt_batch(100, 0.05, 0.15, 1000), 125);
        assert_eq!(adapt_batch(1, 0.05, 0.15, 1000), 2);
        assert_eq!(adapt_batch(999, 0.05, 0.15, 1000), 1000);
        assert_eq!(adapt_batch(1000, 0.05, 0.15, 1000), 1000);
        // dead band: hold
        assert_eq!(adapt_batch(100, 0.20, 0.15, 1000), 100);
        // far above target: shrink, floored at 1
        assert_eq!(adapt_batch(100, 0.9, 0.15, 1000), 80);
        assert_eq!(adapt_batch(1, 0.9, 0.15, 1000), 1);
    }

    #[test]
    fn adaptive_mode_trains_cleanly() {
        // on a 1-core host the controller's wall-clock effect is noise;
        // this asserts the integration is sound (no panic, convergence
        // behaviour intact) — the law itself is unit-tested above.
        let g = generate(DatasetKind::Tiny, Family::Regression, 2.0, 117);
        let mut model = Lasso::new(0.3);
        let res = fit(
            HthcConfig {
                t_a: 1,
                t_b: 2,
                v_b: 1,
                batch_frac: 0.05,
                adaptive_r_tilde: Some(0.15),
                gap_tol: 0.0,
                max_epochs: 60,
                eval_every: 10,
                timeout_secs: 30.0,
                ..Default::default()
            },
            &mut model,
            &g,
        );
        assert_eq!(res.epochs, 60);
        let first = res.trace.points.first().unwrap().objective;
        let last = res.trace.final_objective().unwrap();
        assert!(last < first);
    }

    #[test]
    fn autotune_reports_a_measured_split() {
        let g = generate(DatasetKind::Tiny, Family::Regression, 2.0, 118);
        let mut model = Lasso::new(0.3);
        let res = fit(
            HthcConfig {
                t_a: 2,
                t_b: 2,
                v_b: 1,
                batch_frac: 0.1,
                autotune: true,
                autotune_warmup: 2,
                gap_tol: 0.0,
                max_epochs: 12,
                eval_every: 4,
                timeout_secs: 30.0,
                ..Default::default()
            },
            &mut model,
            &g,
        );
        // the split in effect is reported through extras; the tile
        // granularity is scheduler-legal (block-aligned, nonzero)
        let t_a = res.extras.u64(keys::AUTOTUNE_T_A).expect("split reported");
        let t_b = res.extras.u64(keys::AUTOTUNE_T_B).unwrap();
        let v_b = res.extras.u64(keys::AUTOTUNE_V_B).unwrap();
        let m = res.extras.u64(keys::AUTOTUNE_M).unwrap();
        let tile = res.extras.u64(keys::AUTOTUNE_TILE_COLS).unwrap();
        assert!(t_a >= 1 && t_b >= 1 && v_b >= 1 && m >= 1);
        assert!(tile >= crate::kernels::BLOCK_COLS as u64);
        assert_eq!(tile % crate::kernels::BLOCK_COLS as u64, 0);
        // still optimizes while retuning
        let first = res.trace.points.first().unwrap().objective;
        let last = res.trace.final_objective().unwrap();
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn timeout_is_honoured() {
        let g = generate(DatasetKind::Tiny, Family::Regression, 2.0, 115);
        let mut model = Lasso::new(1e-6); // tiny lambda: slow convergence
        let t = Timer::start();
        let res = fit(
            HthcConfig {
                gap_tol: 1e-300,
                max_epochs: usize::MAX >> 1,
                timeout_secs: 0.3,
                eval_every: 1,
                ..Default::default()
            },
            &mut model,
            &g,
        );
        assert!(!res.converged);
        assert!(t.secs() < 10.0, "timeout must bound the run");
    }
}
