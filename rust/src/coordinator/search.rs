//! Parameter search (the paper's §V-B protocol, first-class).
//!
//! "For each experiment ... we used exhaustive search to find the best
//! parameter settings, i.e., percentage of data updated by B per epoch,
//! and the thread settings T_A, T_B, V_B."  [`grid_search`] runs that
//! protocol over a caller-supplied grid with a per-candidate time
//! budget, returning every result ranked — which also powers the Fig. 6
//! sensitivity analysis (all configurations within a ratio of best).

use super::HthcConfig;
use crate::data::{Dataset, Matrix};
use crate::glm::GlmModel;
use crate::memory::TierSim;
use crate::solver::{Hthc, Problem, Solver};

/// The search grid.
#[derive(Clone, Debug)]
pub struct SearchGrid {
    pub batch_fracs: Vec<f64>,
    pub t_as: Vec<usize>,
    pub t_bs: Vec<usize>,
    pub v_bs: Vec<usize>,
}

impl SearchGrid {
    /// A small host-scale default.
    pub fn small() -> Self {
        SearchGrid {
            batch_fracs: vec![0.02, 0.08, 0.25],
            t_as: vec![1, 2],
            t_bs: vec![1, 2, 4],
            v_bs: vec![1, 2],
        }
    }

    pub fn len(&self) -> usize {
        self.batch_fracs.len() * self.t_as.len() * self.t_bs.len() * self.v_bs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The largest `t_a + t_b * v_b` any candidate in the grid uses
    /// (0 for an empty grid) — checked against the host budget before
    /// a search so oversubscribed grids warn once up front.
    pub fn max_total_threads(&self) -> usize {
        let ta = self.t_as.iter().copied().max().unwrap_or(0);
        let tb = self.t_bs.iter().copied().max().unwrap_or(0);
        let vb = self.v_bs.iter().copied().max().unwrap_or(0);
        if self.is_empty() {
            0
        } else {
            ta + tb * vb
        }
    }
}

/// One evaluated configuration.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub batch_frac: f64,
    pub t_a: usize,
    pub t_b: usize,
    pub v_b: usize,
    /// Seconds to reach the target gap (None = did not converge).
    pub time_to_target: Option<f64>,
    pub epochs: usize,
    pub refresh_frac: f64,
}

impl SearchResult {
    pub fn total_threads(&self) -> usize {
        self.t_a + self.t_b * self.v_b
    }
}

/// Run the grid; `make_model` constructs a fresh model per candidate
/// (search must not leak state across runs).  Results come back sorted:
/// converged candidates by time, then non-converged.
pub fn grid_search(
    make_model: &dyn Fn() -> Box<dyn GlmModel>,
    data: &Dataset,
    grid: &SearchGrid,
    target_gap: f64,
    per_candidate_secs: f64,
    base: &HthcConfig,
    skip_v_b_on_sparse: bool,
) -> Vec<SearchResult> {
    let sparse = matches!(data.matrix(), Matrix::Sparse(_));
    if let Some(budget) = super::config::host_threads() {
        let max = grid.max_total_threads();
        if max > budget {
            eprintln!(
                "warning: search grid peaks at {max} threads but the host has \
                 {budget}; oversubscribed candidates will run slow (and rank \
                 accordingly)"
            );
        }
    }
    let mut out = Vec::new();
    for &frac in &grid.batch_fracs {
        for &t_a in &grid.t_as {
            for &t_b in &grid.t_bs {
                for &v_b in &grid.v_bs {
                    if v_b > 1 && sparse && skip_v_b_on_sparse {
                        continue; // §IV-D: one thread per sparse vector
                    }
                    let cfg = HthcConfig {
                        t_a,
                        t_b,
                        v_b,
                        batch_frac: frac,
                        gap_tol: target_gap,
                        timeout_secs: per_candidate_secs,
                        ..base.clone()
                    };
                    let mut model = make_model();
                    let sim = TierSim::default();
                    let mut problem = Problem::new(model.as_mut(), data, &sim, cfg);
                    let res = Hthc::new().fit(&mut problem);
                    out.push(SearchResult {
                        batch_frac: frac,
                        t_a,
                        t_b,
                        v_b,
                        time_to_target: res.trace.time_to_gap(target_gap),
                        epochs: res.epochs,
                        refresh_frac: res.refresh_frac(),
                    });
                }
            }
        }
    }
    out.sort_by(|a, b| match (a.time_to_target, b.time_to_target) {
        (Some(x), Some(y)) => x.total_cmp(&y),
        (Some(_), None) => std::cmp::Ordering::Less,
        (None, Some(_)) => std::cmp::Ordering::Greater,
        (None, None) => a.epochs.cmp(&b.epochs),
    });
    out
}

/// Fig. 6 view: every converged configuration within `ratio` of the
/// best time.
pub fn near_best(results: &[SearchResult], ratio: f64) -> Vec<&SearchResult> {
    let best = results
        .iter()
        .filter_map(|r| r.time_to_target)
        .fold(f64::INFINITY, f64::min);
    if !best.is_finite() {
        return vec![];
    }
    results
        .iter()
        .filter(|r| r.time_to_target.map_or(false, |t| t <= best * ratio))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetBuilder, DatasetKind, Family};
    use crate::glm::Lasso;

    #[test]
    fn search_ranks_converged_first_and_covers_grid() {
        let g = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .seed(901)
            .build()
            .unwrap();
        let model = Lasso::new(0.4);
        let obj0 = {
            use crate::glm::GlmModel;
            model.objective(&vec![0.0; g.d()], g.targets(), &vec![0.0; g.n()])
        };
        let grid = SearchGrid {
            batch_fracs: vec![0.25, 1.0],
            t_as: vec![1],
            t_bs: vec![1, 2],
            v_bs: vec![1],
        };
        let base = HthcConfig { max_epochs: 3000, eval_every: 5, ..Default::default() };
        let results = grid_search(
            &|| Box::new(Lasso::new(0.4)),
            &g,
            &grid,
            1e-3 * obj0,
            20.0,
            &base,
            true,
        );
        assert_eq!(results.len(), grid.len());
        assert!(results[0].time_to_target.is_some(), "best must converge");
        // sorted: all converged before any unconverged
        let first_none = results.iter().position(|r| r.time_to_target.is_none());
        if let Some(k) = first_none {
            assert!(results[k..].iter().all(|r| r.time_to_target.is_none()));
        }
        // near-best contains at least the winner
        let nb = near_best(&results, 1.1);
        assert!(!nb.is_empty());
    }

    #[test]
    fn sparse_grid_skips_v_b() {
        let g = DatasetBuilder::generated(DatasetKind::News20Like, Family::Regression)
            .scale(0.03)
            .seed(902)
            .build()
            .unwrap();
        let grid = SearchGrid {
            batch_fracs: vec![0.5],
            t_as: vec![1],
            t_bs: vec![1],
            v_bs: vec![1, 2, 4],
        };
        let base = HthcConfig { max_epochs: 3, eval_every: 3, ..Default::default() };
        let results = grid_search(
            &|| Box::new(Lasso::new(0.4)),
            &g,
            &grid,
            0.0,
            5.0,
            &base,
            true,
        );
        assert_eq!(results.len(), 1, "v_b > 1 rows skipped for sparse");
    }

    #[test]
    fn max_total_threads_tracks_the_heaviest_candidate() {
        let grid = SearchGrid {
            batch_fracs: vec![0.1],
            t_as: vec![1, 4],
            t_bs: vec![2, 3],
            v_bs: vec![1, 2],
        };
        assert_eq!(grid.max_total_threads(), 4 + 3 * 2);
        let empty = SearchGrid {
            batch_fracs: vec![],
            t_as: vec![4],
            t_bs: vec![2],
            v_bs: vec![2],
        };
        assert_eq!(empty.max_total_threads(), 0);
    }

    #[test]
    fn near_best_empty_when_nothing_converges() {
        let r = vec![SearchResult {
            batch_frac: 0.1,
            t_a: 1,
            t_b: 1,
            v_b: 1,
            time_to_target: None,
            epochs: 5,
            refresh_frac: 0.5,
        }];
        assert!(near_best(&r, 1.1).is_empty());
    }
}
