//! Coordinate selection at the epoch boundary (paper §II-B/C).
//!
//! The paper's scheme picks the `m` coordinates with the largest
//! (stale) duality-gap values; random and importance-sampling selection
//! are provided as the comparators the paper discusses ("any adaptive
//! selection scheme could be adopted").

use crate::util::Rng;
use std::cmp::Ordering;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Selection {
    /// Greedy top-m by gap value (the paper's choice, after [10]).
    DualityGap,
    /// Uniform random without replacement.
    Random,
    /// Importance sampling proportional to gap values
    /// (Efraimidis–Spirakis reservoir keys), without replacement.
    Importance,
}

impl Selection {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "gap" | "duality-gap" => Selection::DualityGap,
            "random" => Selection::Random,
            "importance" => Selection::Importance,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Selection::DualityGap => "duality-gap",
            Selection::Random => "random",
            Selection::Importance => "importance",
        }
    }

    /// Select `m` distinct coordinates from the gap values `z`.
    ///
    /// Coordinates task A has never measured carry `z_i = +inf`; a
    /// deterministic top-m would keep re-picking the same lowest-index
    /// unmeasured block forever and starve the rest.  Unmeasured
    /// entries therefore get *randomized* priorities above every
    /// finite gap — they are still explored first, but uniformly.
    pub fn select(self, z: &[f32], m: usize, rng: &mut Rng) -> Vec<usize> {
        let n = z.len();
        let m = m.min(n);
        match self {
            Selection::Random => rng.sample_distinct(n, m),
            Selection::DualityGap => {
                if z.iter().any(|v| !v.is_finite()) {
                    // Unmeasured priorities live in [2*base, 3*base], so
                    // `base` is capped at f32::MAX/4 to keep them *finite*
                    // even when measured gaps approach f32::MAX.  The old
                    // uncapped `zmax * (2 + r)` overflowed to +inf there,
                    // and equal +inf priorities degenerate top_m into
                    // keep-the-first-m — exactly the lowest-index
                    // starvation the randomization exists to prevent.
                    // Measured gaps are clamped to `base` (order-preserving
                    // below the cap, which only pathological gaps exceed),
                    // so every unmeasured entry still outranks every
                    // measured one.
                    let base = z
                        .iter()
                        .copied()
                        .filter(|v| v.is_finite())
                        .fold(0.0f32, f32::max)
                        .clamp(1.0, f32::MAX / 4.0);
                    let adjusted: Vec<f32> = z
                        .iter()
                        .map(|&v| {
                            if v.is_finite() {
                                v.min(base)
                            } else {
                                base * (2.0 + rng.f32())
                            }
                        })
                        .collect();
                    debug_assert!(adjusted.iter().all(|p| p.is_finite()));
                    top_m(&adjusted, m)
                } else {
                    top_m(z, m)
                }
            }
            Selection::Importance => importance_sample(z, m, rng),
        }
    }
}

/// Indices of the `m` largest values — O(n log m) via a min-heap of the
/// current candidates (the selection runs with both tasks paused, so it
/// sits on the epoch-boundary critical path; see bench `perf_hotpath`).
pub fn top_m(z: &[f32], m: usize) -> Vec<usize> {
    use std::collections::BinaryHeap;

    #[derive(PartialEq)]
    struct Entry(f32, usize); // min-heap on value
    impl Eq for Entry {}
    impl PartialOrd for Entry {
        fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Entry {
        fn cmp(&self, o: &Self) -> Ordering {
            // reversed (min-heap); NaN sorts low so it is evicted first
            o.0.partial_cmp(&self.0)
                .unwrap_or(Ordering::Equal)
                .then(o.1.cmp(&self.1))
        }
    }

    let m = m.min(z.len());
    if m == 0 {
        return vec![];
    }
    let mut heap: BinaryHeap<Entry> = BinaryHeap::with_capacity(m + 1);
    for (i, &v) in z.iter().enumerate() {
        let v = if v.is_nan() { f32::NEG_INFINITY } else { v };
        if heap.len() < m {
            heap.push(Entry(v, i));
        } else if heap.peek().is_some_and(|top| v > top.0) {
            heap.pop();
            heap.push(Entry(v, i));
        }
    }
    let mut out: Vec<usize> = heap.into_iter().map(|e| e.1).collect();
    out.sort_unstable();
    out
}

/// Weighted sampling without replacement (Efraimidis–Spirakis): draw
/// key `ln(u_i) / w_i` and keep the top m.  Zero/negative weights get
/// -inf keys (never selected unless everything is zero).
fn importance_sample(z: &[f32], m: usize, rng: &mut Rng) -> Vec<usize> {
    let keys: Vec<f32> = z
        .iter()
        .map(|&w| {
            let w = if w.is_finite() { w.max(0.0) } else { f32::MAX };
            if w > 0.0 {
                (rng.f64().max(1e-300).ln() / w as f64) as f32
            } else {
                f32::NEG_INFINITY
            }
        })
        .collect();
    let picked = top_m(&keys, m);
    if keys.iter().all(|&k| k == f32::NEG_INFINITY) {
        // degenerate: all-zero gaps — fall back to uniform
        return rng.sample_distinct(z.len(), m);
    }
    picked
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_m_exact() {
        let z = vec![0.1, 5.0, 3.0, 0.2, 4.0];
        assert_eq!(top_m(&z, 3), vec![1, 2, 4]);
        assert_eq!(top_m(&z, 0), Vec::<usize>::new());
        assert_eq!(top_m(&z, 99), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn top_m_handles_inf_and_nan() {
        let z = vec![f32::NAN, f32::INFINITY, 1.0, f32::NEG_INFINITY];
        assert_eq!(top_m(&z, 2), vec![1, 2]);
    }

    #[test]
    fn selection_returns_distinct_sorted_indices() {
        let mut rng = Rng::new(71);
        let z: Vec<f32> = (0..100).map(|i| (i % 13) as f32).collect();
        for sel in [Selection::DualityGap, Selection::Random, Selection::Importance] {
            let got = sel.select(&z, 20, &mut rng);
            assert_eq!(got.len(), 20, "{}", sel.name());
            let set: std::collections::HashSet<_> = got.iter().collect();
            assert_eq!(set.len(), 20);
            assert!(got.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn importance_prefers_large_gaps() {
        let mut rng = Rng::new(72);
        // coordinate 7 has weight 1000x others: should almost always be in
        let mut z = vec![0.001f32; 50];
        z[7] = 1.0;
        let mut hits = 0;
        for _ in 0..100 {
            if importance_sample(&z, 5, &mut rng).contains(&7) {
                hits += 1;
            }
        }
        assert!(hits > 90, "{hits}/100");
    }

    #[test]
    fn importance_all_zero_falls_back_to_uniform() {
        let mut rng = Rng::new(73);
        let z = vec![0.0f32; 30];
        let got = importance_sample(&z, 10, &mut rng);
        assert_eq!(got.len(), 10);
    }

    #[test]
    fn gap_selection_beats_random_on_skewed_gaps() {
        // sanity for the paper's core premise: with skewed importance,
        // top-m captures more total gap than random.
        let mut rng = Rng::new(74);
        let z: Vec<f32> = (0..1000)
            .map(|_| if rng.f32() < 0.05 { 10.0 } else { 0.01 })
            .collect();
        let sum = |idx: &[usize]| idx.iter().map(|&i| z[i] as f64).sum::<f64>();
        let greedy = sum(&Selection::DualityGap.select(&z, 50, &mut rng));
        let random = sum(&Selection::Random.select(&z, 50, &mut rng));
        assert!(greedy > 3.0 * random, "greedy {greedy} vs random {random}");
    }

    /// Regression (issue 4): with finite gaps near f32::MAX, the
    /// unmeasured-entry priority `zmax * (2 + r)` overflowed to +inf,
    /// all unmeasured entries tied, and top_m degenerated into always
    /// picking the lowest-index unmeasured block — the starvation the
    /// randomization is documented to prevent.  The clamped priorities
    /// must stay finite, still rank every unmeasured entry above every
    /// measured one, and actually vary across draws.
    #[test]
    fn huge_finite_gaps_do_not_collapse_unmeasured_tiebreak() {
        let m = 5;
        // measured gaps in 0..50 (near f32::MAX), unmeasured in 50..100
        let mut z = vec![f32::MAX / 1.5; 50];
        z.extend_from_slice(&[f32::INFINITY; 50]);
        let mut union = std::collections::HashSet::new();
        for seed in 0..20u64 {
            let mut rng = Rng::new(800 + seed);
            let got = Selection::DualityGap.select(&z, m, &mut rng);
            assert_eq!(got.len(), m);
            for &j in &got {
                assert!(j >= 50, "unmeasured entries must outrank measured ones, got {j}");
            }
            union.extend(got);
        }
        assert!(union.len() > m, "selection must vary across draws, got {union:?}");
    }

    #[test]
    fn parse_roundtrip() {
        for s in [Selection::DualityGap, Selection::Random, Selection::Importance] {
            assert_eq!(Selection::parse(s.name()), Some(s));
        }
        assert_eq!(Selection::parse("bogus"), None);
    }
}
