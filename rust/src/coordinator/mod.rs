//! The HTHC coordinator (paper §III/§IV) — the system contribution.
//!
//! Two heterogeneous tasks run concurrently on disjoint worker pools:
//!
//! * **Task A** ([`task_a`]) sweeps randomly over *all* columns with the
//!   epoch-start snapshot `(v, alpha)` and refreshes the gap memory
//!   `z_i = gap(<w, d_i>, alpha_i)`;
//! * **Task B** ([`task_b`]) runs asynchronous parallel SCD over the
//!   selected batch: `T_B` concurrent coordinate updates, each optionally
//!   split across `V_B` threads, with medium-grained locks on the shared
//!   vector `v` (§IV-C).
//!
//! At each epoch boundary the leader selects the next batch from the
//! (partially stale) gap memory, swaps B's working set in the fast
//! memory tier, recomputes the `w` snapshot for A, and restarts both
//! pools (§III, Fig. 1).
//!
//! The §IV-F performance model ([`perf_model`]) chooses
//! `m, T_A, T_B, V_B` from a measured table of per-update times; in
//! `--autotune` mode the [`AutoTuner`] re-solves the same program from
//! live [`crate::memory::TierSim`] counters and per-epoch timings.

pub mod config;
pub mod gap_memory;
pub mod hthc;
pub mod perf_model;
pub mod search;
pub mod selection;
pub mod shared_vec;
pub mod task_a;
pub mod task_b;
pub mod working_set;

pub use config::{host_threads, HthcConfig};
pub use gap_memory::GapMemory;
pub use hthc::HthcSolver;
pub use perf_model::{
    tile_cols_for, AutoTuner, EpochMeasurement, MeasuredCosts, PerfModel, Recommendation,
};
pub use search::{grid_search, near_best, SearchGrid, SearchResult};
pub use selection::Selection;
pub use shared_vec::SharedVector;
pub use working_set::WorkingSet;
