//! HTHC run configuration (the paper's tunables: §IV-F).

use super::selection::Selection;

/// All knobs of one HTHC run.  Field names follow the paper:
/// `T_A` threads for task A, `T_B` parallel updates on task B, `V_B`
/// threads per vector operation, `%B` = `batch_frac` of coordinates
/// updated by B per epoch.
#[derive(Clone, Debug, PartialEq)]
pub struct HthcConfig {
    /// Threads computing gap-memory updates (paper caps at 24: DRAM
    /// bandwidth saturation, Fig. 2).
    pub t_a: usize,
    /// Parallel coordinate updates on task B.
    pub t_b: usize,
    /// Threads per vector operation within one update (dense long
    /// vectors only; 1 is best below d ~ 130k, Fig. 3).
    pub v_b: usize,
    /// Fraction of coordinates B updates per epoch (the paper's %B).
    pub batch_frac: f64,
    /// Coordinate selection scheme (duality-gap is the paper's).
    pub selection: Selection,
    /// Stop when the total duality gap falls below this.
    pub gap_tol: f64,
    /// Hard epoch cap.
    pub max_epochs: usize,
    /// Hard wall-clock cap (seconds).
    pub timeout_secs: f64,
    /// Shared-vector lock granularity in elements (paper: 1024).
    pub lock_chunk: usize,
    /// Epochs between exact convergence evaluations (gap over all
    /// coordinates — not free, so not every epoch).
    pub eval_every: usize,
    /// PRNG seed (A's sampling, selection tie-breaking, shuffles).
    pub seed: u64,
    /// Route task A's bulk gap computation through the PJRT artifacts
    /// (L1/L2 path) instead of the native loops, when available.
    pub use_pjrt_gaps: bool,
    /// Online batch-size control: adjust `m` each epoch to keep task A's
    /// refresh fraction near this target (the §IV-F constraint r~ as a
    /// feedback controller instead of an offline table).  None = fixed
    /// `batch_frac`.
    pub adaptive_r_tilde: Option<f64>,
}

impl Default for HthcConfig {
    fn default() -> Self {
        HthcConfig {
            t_a: 4,
            t_b: 2,
            v_b: 1,
            batch_frac: 0.08,
            selection: Selection::DualityGap,
            gap_tol: 1e-5,
            max_epochs: 200,
            timeout_secs: 120.0,
            lock_chunk: 1024,
            eval_every: 1,
            seed: 42,
            use_pjrt_gaps: false,
            adaptive_r_tilde: None,
        }
    }
}

impl HthcConfig {
    /// Batch size `m` for a problem with `n` coordinates (at least 1).
    pub fn batch_size(&self, n: usize) -> usize {
        ((n as f64 * self.batch_frac).round() as usize).clamp(1, n)
    }

    /// Total threads this configuration uses (paper's T_total).
    pub fn total_threads(&self) -> usize {
        self.t_a + self.t_b * self.v_b
    }

    /// Panic-early validation with actionable messages.
    pub fn validate(&self) {
        assert!(self.t_a >= 1, "t_a must be >= 1");
        assert!(self.t_b >= 1, "t_b must be >= 1");
        assert!(self.v_b >= 1, "v_b must be >= 1");
        assert!(
            self.batch_frac > 0.0 && self.batch_frac <= 1.0,
            "batch_frac in (0, 1]"
        );
        assert!(self.lock_chunk >= 1, "lock_chunk must be >= 1");
        assert!(self.eval_every >= 1, "eval_every must be >= 1");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_size_clamps() {
        let mut c = HthcConfig::default();
        c.batch_frac = 0.1;
        assert_eq!(c.batch_size(100), 10);
        c.batch_frac = 1e-9;
        assert_eq!(c.batch_size(100), 1);
        c.batch_frac = 1.0;
        assert_eq!(c.batch_size(100), 100);
    }

    #[test]
    fn total_threads_matches_paper_formula() {
        let c = HthcConfig { t_a: 12, t_b: 8, v_b: 6, ..Default::default() };
        assert_eq!(c.total_threads(), 12 + 48); // Table II epsilon row
    }

    #[test]
    #[should_panic]
    fn zero_threads_rejected() {
        HthcConfig { t_b: 0, ..Default::default() }.validate();
    }
}
