//! HTHC run configuration (the paper's tunables: §IV-F).

use super::selection::Selection;

/// All knobs of one HTHC run.  Field names follow the paper:
/// `T_A` threads for task A, `T_B` parallel updates on task B, `V_B`
/// threads per vector operation, `%B` = `batch_frac` of coordinates
/// updated by B per epoch.
#[derive(Clone, Debug, PartialEq)]
pub struct HthcConfig {
    /// Threads computing gap-memory updates (paper caps at 24: DRAM
    /// bandwidth saturation, Fig. 2).
    pub t_a: usize,
    /// Parallel coordinate updates on task B.
    pub t_b: usize,
    /// Threads per vector operation within one update (dense long
    /// vectors only; 1 is best below d ~ 130k, Fig. 3).
    pub v_b: usize,
    /// Fraction of coordinates B updates per epoch (the paper's %B).
    pub batch_frac: f64,
    /// Coordinate selection scheme (duality-gap is the paper's).
    pub selection: Selection,
    /// Stop when the total duality gap falls below this.
    pub gap_tol: f64,
    /// Hard epoch cap.
    pub max_epochs: usize,
    /// Hard wall-clock cap (seconds).
    pub timeout_secs: f64,
    /// Shared-vector lock granularity in elements (paper: 1024).
    pub lock_chunk: usize,
    /// Epochs between exact convergence evaluations (gap over all
    /// coordinates — not free, so not every epoch).
    pub eval_every: usize,
    /// PRNG seed (A's sampling, selection tie-breaking, shuffles).
    pub seed: u64,
    /// Route task A's bulk gap computation through the PJRT artifacts
    /// (L1/L2 path) instead of the native loops, when available.
    pub use_pjrt_gaps: bool,
    /// Online batch-size control: adjust `m` each epoch to keep task A's
    /// refresh fraction near this target (the §IV-F constraint r~ as a
    /// feedback controller instead of an offline table).  None = fixed
    /// `batch_frac`.
    pub adaptive_r_tilde: Option<f64>,
    /// Refine the `(t_a, t_b, v_b, m, tile)` split after a few epochs
    /// from *measured* tier traffic and timings (the §IV-F program over
    /// an [`crate::coordinator::AutoTuner`]-calibrated table instead of
    /// installation-time constants).
    pub autotune: bool,
    /// Epochs to observe before the autotuner refines the split.
    pub autotune_warmup: usize,
}

impl Default for HthcConfig {
    fn default() -> Self {
        HthcConfig {
            t_a: 4,
            t_b: 2,
            v_b: 1,
            batch_frac: 0.08,
            selection: Selection::DualityGap,
            gap_tol: 1e-5,
            max_epochs: 200,
            timeout_secs: 120.0,
            lock_chunk: 1024,
            eval_every: 1,
            seed: 42,
            use_pjrt_gaps: false,
            adaptive_r_tilde: None,
            autotune: false,
            autotune_warmup: 3,
        }
    }
}

/// Hardware threads available to this process, when the platform can
/// tell us (`std::thread::available_parallelism`).
pub fn host_threads() -> Option<usize> {
    std::thread::available_parallelism().ok().map(|n| n.get())
}

impl HthcConfig {
    /// Batch size `m` for a problem with `n` coordinates (at least 1).
    pub fn batch_size(&self, n: usize) -> usize {
        ((n as f64 * self.batch_frac).round() as usize).clamp(1, n)
    }

    /// Total threads this configuration uses (paper's T_total).
    pub fn total_threads(&self) -> usize {
        self.t_a + self.t_b * self.v_b
    }

    /// Panic-early validation with actionable messages.  Thread-count
    /// *oversubscription* is a warning, not an error: the paper's
    /// splits assume a 72-core KNL and must still run (slowly) on small
    /// hosts, and the oversubscription CI job depends on that.
    pub fn validate(&self) {
        assert!(self.t_a >= 1, "t_a must be >= 1");
        assert!(self.t_b >= 1, "t_b must be >= 1");
        assert!(self.v_b >= 1, "v_b must be >= 1");
        assert!(
            self.batch_frac > 0.0 && self.batch_frac <= 1.0,
            "batch_frac in (0, 1]"
        );
        assert!(self.lock_chunk >= 1, "lock_chunk must be >= 1");
        assert!(self.eval_every >= 1, "eval_every must be >= 1");
        assert!(self.autotune_warmup >= 1, "autotune_warmup must be >= 1");
        if let Some(budget) = host_threads() {
            if let Some(msg) = self.oversubscription_warning(budget) {
                eprintln!("warning: {msg}");
            }
        }
    }

    /// The warning text when `t_a + t_b * v_b` oversubscribes a
    /// `budget`-thread machine, else `None`.  Split out from
    /// [`HthcConfig::validate`] so tests can probe the message without
    /// depending on the host's core count.
    pub fn oversubscription_warning(&self, budget: usize) -> Option<String> {
        let total = self.total_threads();
        if total > budget {
            Some(format!(
                "config uses {total} threads (t_a={} + t_b={} * v_b={}) but the host \
                 has {budget}; expect contention — consider `--autotune` or \
                 clamped_to({budget})",
                self.t_a, self.t_b, self.v_b
            ))
        } else {
            None
        }
    }

    /// A copy shrunk to fit a `budget`-thread machine: first collapse
    /// the vector lanes (`v_b -> 1`, the knob with the worst
    /// oversubscription behavior — barrier spins), then shed B groups,
    /// then A threads, never dropping either task below one thread.
    pub fn clamped_to(&self, budget: usize) -> HthcConfig {
        let mut c = self.clone();
        if c.total_threads() > budget {
            c.v_b = 1;
        }
        while c.total_threads() > budget && c.t_b > 1 {
            c.t_b -= 1;
        }
        while c.total_threads() > budget && c.t_a > 1 {
            c.t_a -= 1;
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_size_clamps() {
        let mut c = HthcConfig::default();
        c.batch_frac = 0.1;
        assert_eq!(c.batch_size(100), 10);
        c.batch_frac = 1e-9;
        assert_eq!(c.batch_size(100), 1);
        c.batch_frac = 1.0;
        assert_eq!(c.batch_size(100), 100);
    }

    #[test]
    fn total_threads_matches_paper_formula() {
        let c = HthcConfig { t_a: 12, t_b: 8, v_b: 6, ..Default::default() };
        assert_eq!(c.total_threads(), 12 + 48); // Table II epsilon row
    }

    #[test]
    #[should_panic]
    fn zero_threads_rejected() {
        HthcConfig { t_b: 0, ..Default::default() }.validate();
    }

    #[test]
    fn oversubscription_warns_but_does_not_reject() {
        let c = HthcConfig { t_a: 6, t_b: 4, v_b: 2, ..Default::default() };
        // 14 threads on an explicit 8-thread budget: warning text names
        // the arithmetic; a roomy budget stays silent.
        let msg = c.oversubscription_warning(8).expect("14 > 8 warns");
        assert!(msg.contains("14 threads"), "{msg}");
        assert!(msg.contains("has 8"), "{msg}");
        assert!(c.oversubscription_warning(14).is_none(), "exact fit is fine");
        assert!(c.oversubscription_warning(64).is_none());
        // validate() must not panic for oversubscribed-but-sane configs
        c.validate();
    }

    #[test]
    fn clamp_sheds_lanes_then_groups_then_a_threads() {
        let c = HthcConfig { t_a: 6, t_b: 4, v_b: 2, ..Default::default() };
        // budget 8: v_b -> 1 (10 left), then t_b 4 -> 2 (8 fits)
        let c8 = c.clamped_to(8);
        assert_eq!((c8.t_a, c8.t_b, c8.v_b), (6, 2, 1));
        assert!(c8.total_threads() <= 8);
        // budget 2: both tasks keep their last thread
        let c2 = c.clamped_to(2);
        assert_eq!((c2.t_a, c2.t_b, c2.v_b), (1, 1, 1));
        assert_eq!(c2.total_threads(), 2);
        // already-fitting configs come back unchanged
        let fit = HthcConfig { t_a: 2, t_b: 1, v_b: 1, ..Default::default() };
        assert_eq!(fit.clamped_to(4), fit);
        // the clamp result never warns on its own budget
        assert!(c8.oversubscription_warning(8).is_none());
    }
}
