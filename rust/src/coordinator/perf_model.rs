//! The §IV-F performance model.
//!
//! `t_{I,d}(threads)` — the time of a single coordinate update on task
//! `I` for vector length `d` — "is not trivial to derive [...] thus we
//! precompute the values for different thread setups and d during
//! installation and store them in a table."  [`PerfModel::calibrate`]
//! is that installation step (micro-benchmarks on synthetic data), and
//! [`PerfModel::recommend`] solves the paper's optimization:
//!
//! ```text
//! min_{m, T_A, T_B, V_B}  m * t_B,d(T_B, V_B)
//!     s.t.  m * t_B,d(T_B, V_B) / t_A,d(T_A)  >=  r~ * n
//! ```
//!
//! i.e. pick the fastest-B configuration whose epoch still leaves task A
//! enough time to refresh at least `r~` (~15%) of the gap memory.
//!
//! On this 1-core host the measured table cannot exhibit parallel
//! scaling, so calibration composes a *measured* single-thread
//! per-element cost with the [`TierSim`] bandwidth model (Fig. 2/3
//! shapes: near-linear until channel saturation, decline beyond; B's
//! extra V_B synchronization overhead grows with lanes).  Both the
//! measured constant and the modeled curve are reported.

use crate::memory::{Tier, TierSim};
use crate::util::Timer;

/// One table row: seconds per coordinate update.
#[derive(Clone, Copy, Debug)]
pub struct Entry {
    pub d: usize,
    pub threads: usize,   // T_A (task A) or T_B (task B)
    pub v_threads: usize, // V_B; 1 for task A
    pub secs_per_update: f64,
}

/// Recommendation from the model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Recommendation {
    pub m: usize,
    pub t_a: usize,
    pub t_b: usize,
    pub v_b: usize,
    /// Modeled epoch time (seconds).
    pub epoch_secs: f64,
    /// Modeled fraction of z refreshed per epoch.
    pub refresh_frac: f64,
    /// Column-tile granularity for the [`crate::sched::TileScheduler`]
    /// at the recommended `t_a` (BLOCK_COLS-aligned).
    pub tile_cols: usize,
}

/// Pick a tile granularity for a scheduler over `n` columns and `t_a`
/// workers: aim for ~64 tiles per shard (enough claims that stealing
/// can balance, few enough that claim overhead stays negligible),
/// rounded down to a [`crate::kernels::BLOCK_COLS`] multiple and never
/// below one block.
pub fn tile_cols_for(n: usize, t_a: usize) -> usize {
    let b = crate::kernels::BLOCK_COLS;
    let shard = n / t_a.max(1);
    ((shard / 64) / b * b).max(b)
}

/// The calibrated table.
pub struct PerfModel {
    pub a_entries: Vec<Entry>,
    pub b_entries: Vec<Entry>,
    /// Measured single-thread per-element dot cost (secs/element).
    pub per_elem_secs: f64,
    /// V_B synchronization cost per barrier crossing (secs).
    pub sync_secs: f64,
}

/// Per-update work in bytes for vector length d (col read + v touch).
fn update_bytes(d: usize) -> u64 {
    (d * 4 * 2) as u64
}

// --- KNL calibration constants for the *modeled* curves -----------------
// The modeled table reproduces the paper's machine (not this host):
// 72 cores @ 1.5 GHz, DRAM ~80 GB/s, MCDRAM ~440 GB/s.

/// Per-core flops/cycle of task A's gap sweep on KNL.  Derived from
/// Fig. 2: aggregate ~10 flops/cycle at the ~20-thread DRAM saturation
/// point -> ~0.5 per core.
pub const KNL_A_CORE_FPC: f64 = 0.5;

/// Whole-coordinate-update flops/cycle on KNL (paper §IV-A3: "our
/// entire coordinate update achieves about 7.2 flops/cycle").
pub const KNL_B_FPC: f64 = 7.2;

/// Counter-barrier crossing cost on KNL (mutex-protected counters over
/// a handful of threads; calibrated so the V_B crossover lands at the
/// paper's d ~ 130k, Fig. 3).
pub const KNL_SYNC_SECS: f64 = 2.7e-6;

impl PerfModel {
    /// Measure the host constants and build the table for the given
    /// vector lengths and thread counts.
    pub fn calibrate(ds: &[usize], t_as: &[usize], t_bs: &[usize], v_bs: &[usize]) -> Self {
        // Measure single-thread per-element dot cost on a warm buffer.
        let d_probe = 1 << 16;
        let x = vec![1.000_1f32; d_probe];
        let w = vec![0.999_9f32; d_probe];
        let mut acc = 0.0f32;
        let (secs, _) = crate::util::timer::bench_median(
            || {
                acc += crate::kernels::dot(&x, &w);
            },
            0.05,
            200,
        );
        std::hint::black_box(acc);
        let per_elem_secs = secs / d_probe as f64;

        let sync_secs = measure_sync_secs();

        let mut model = PerfModel {
            a_entries: Vec::new(),
            b_entries: Vec::new(),
            per_elem_secs,
            sync_secs,
        };
        let sim = TierSim::default();
        for &d in ds {
            for &ta in t_as {
                model.a_entries.push(Entry {
                    d,
                    threads: ta,
                    v_threads: 1,
                    secs_per_update: model.modeled_a_update(&sim, d, ta),
                });
            }
            for &tb in t_bs {
                for &vb in v_bs {
                    model.b_entries.push(Entry {
                        d,
                        threads: tb,
                        v_threads: vb,
                        secs_per_update: model.modeled_b_update(&sim, d, tb, vb),
                    });
                }
            }
        }
        model
    }

    /// Modeled time of one task-A update (gap refresh) at T_A threads on
    /// the paper's KNL: each of the T_A concurrent streamers gets a
    /// 1/T_A share of the (saturating) DRAM bandwidth, floored by the
    /// per-core compute rate.  Aggregate throughput therefore follows
    /// Fig. 2: near-linear to ~20 threads, flat to 24, declining after.
    pub fn modeled_a_update(&self, sim: &TierSim, d: usize, t_a: usize) -> f64 {
        let per_thread_gbs = sim.effective_gbs(Tier::Slow, t_a) / t_a.max(1) as f64;
        let bw_secs = update_bytes(d) as f64 / (per_thread_gbs * 1e9);
        // 2d flops at the per-core rate:
        let compute_secs =
            2.0 * d as f64 / (KNL_A_CORE_FPC * crate::util::timer::KNL_HZ);
        bw_secs.max(compute_secs)
    }

    /// Modeled time of one task-B update at (T_B, V_B) on KNL: MCDRAM is
    /// hard to saturate (the paper's VTune finding: L2-per-tile is the
    /// bottleneck, bandwidth headroom remains), so the compute rate of
    /// 7.2 flops/cycle per update dominates; V_B splits the vector but
    /// pays 3 barrier crossings per update across its lanes (§IV-B),
    /// which is why V_B > 1 only pays off for very long vectors (Fig 3).
    pub fn modeled_b_update(&self, sim: &TierSim, d: usize, t_b: usize, v_b: usize) -> f64 {
        let streams = t_b * v_b;
        let per_stream_gbs = sim.effective_gbs(Tier::Fast, streams) / streams as f64;
        // dot + axpy stream the column twice (v stays L2-resident per
        // the §IV-A2 chunk sizing); each of the V_B lanes moves 1/V_B:
        let bw_secs =
            2.0 * update_bytes(d) as f64 / (per_stream_gbs * 1e9 * v_b as f64);
        // 4d flops per update at 7.2 f/c, split across V_B lanes:
        let compute_secs =
            4.0 * d as f64 / (KNL_B_FPC * crate::util::timer::KNL_HZ * v_b as f64);
        let sync = if v_b > 1 { 3.0 * KNL_SYNC_SECS * v_b as f64 } else { 0.0 };
        // chunk-lock contention grows mildly with concurrent writers
        let lock = 2e-7 * (t_b.saturating_sub(1)) as f64;
        compute_secs.max(bw_secs) + sync + lock
    }

    fn lookup(entries: &[Entry], d: usize, threads: usize, v_threads: usize) -> Option<f64> {
        // nearest-d row with exact thread match
        entries
            .iter()
            .filter(|e| e.threads == threads && e.v_threads == v_threads)
            .min_by_key(|e| e.d.abs_diff(d))
            .map(|e| e.secs_per_update)
    }

    pub fn t_a(&self, d: usize, threads: usize) -> Option<f64> {
        Self::lookup(&self.a_entries, d, threads, 1)
    }

    pub fn t_b(&self, d: usize, t_b: usize, v_b: usize) -> Option<f64> {
        Self::lookup(&self.b_entries, d, t_b, v_b)
    }

    /// Solve the §IV-F program by enumeration over the table, for a
    /// problem with `n` coordinates of length `d`, staleness target
    /// `r_tilde`, batch-size candidates `fracs`, and a total thread
    /// budget (T_A + T_B * V_B <= budget).
    pub fn recommend(
        &self,
        n: usize,
        d: usize,
        r_tilde: f64,
        fracs: &[f64],
        thread_budget: usize,
    ) -> Option<Recommendation> {
        let mut best: Option<Recommendation> = None;
        let t_as: Vec<usize> = dedup_sorted(self.a_entries.iter().map(|e| e.threads));
        let t_bs: Vec<usize> = dedup_sorted(self.b_entries.iter().map(|e| e.threads));
        let v_bs: Vec<usize> = dedup_sorted(self.b_entries.iter().map(|e| e.v_threads));
        for &frac in fracs {
            let m = ((n as f64 * frac).round() as usize).clamp(1, n);
            for &ta in &t_as {
                let Some(ta_secs) = self.t_a(d, ta) else { continue };
                for &tb in &t_bs {
                    for &vb in &v_bs {
                        if ta + tb * vb > thread_budget {
                            continue;
                        }
                        let Some(tb_secs) = self.t_b(d, tb, vb) else { continue };
                        let epoch = m as f64 * tb_secs;
                        // A updates during the epoch, across T_A threads:
                        let a_updates = epoch / ta_secs * ta as f64;
                        let refresh = (a_updates / n as f64).min(1.0);
                        if a_updates < r_tilde * n as f64 {
                            continue; // constraint violated
                        }
                        let cand = Recommendation {
                            m,
                            t_a: ta,
                            t_b: tb,
                            v_b: vb,
                            epoch_secs: epoch,
                            refresh_frac: refresh,
                            tile_cols: tile_cols_for(n, ta),
                        };
                        if best.map_or(true, |b| cand.epoch_secs < b.epoch_secs) {
                            best = Some(cand);
                        }
                    }
                }
            }
        }
        best
    }
}

fn dedup_sorted(it: impl Iterator<Item = usize>) -> Vec<usize> {
    let mut v: Vec<usize> = it.collect();
    v.sort_unstable();
    v.dedup();
    v
}

/// Measure the spin-barrier crossing cost with 2 real participants —
/// the per-barrier price V_B pays (3 crossings/update).  Shared by
/// [`PerfModel::calibrate`] and [`AutoTuner`].
fn measure_sync_secs() -> f64 {
    let b = crate::threadpool::SpinBarrier::new(2);
    let rounds = 2000;
    let t = Timer::start();
    std::thread::scope(|s| {
        for _ in 0..2 {
            s.spawn(|| {
                for _ in 0..rounds {
                    b.wait();
                }
            });
        }
    });
    t.secs() / rounds as f64
}

// --- Autotuning from measured traffic -----------------------------------

/// What one concurrent A+B epoch actually cost, as observed by the
/// solver: wall seconds of the run phase (swap/eval excluded) plus the
/// update counts and the [`TierSim`] read-counter deltas over exactly
/// that phase.
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochMeasurement {
    /// Wall seconds of the concurrent A+B phase.
    pub run_secs: f64,
    /// Task-A gap refreshes performed in the phase.
    pub a_updates: u64,
    /// Task-B coordinate updates performed in the phase.
    pub b_updates: u64,
    /// Slow-tier read-byte delta over the phase (task A's sweep).
    pub slow_read_bytes: u64,
    /// Fast-tier read-byte delta over the phase (task B's working set).
    pub fast_read_bytes: u64,
}

/// Host costs distilled from the observed epochs — the measured
/// replacement for the KNL constants in the modeled table.
#[derive(Clone, Copy, Debug)]
pub struct MeasuredCosts {
    /// Slow-tier bytes one gap refresh streams (col read, as charged).
    pub a_bytes_per_update: f64,
    /// Aggregate slow-tier read bandwidth task A achieved (GB/s) at the
    /// observed `t_a`.
    pub agg_slow_gbs: f64,
    /// Fast-tier bytes one coordinate update streams.
    pub b_bytes_per_update: f64,
    /// Observed wall seconds per task-B update (at the observed split).
    pub b_update_secs: f64,
    /// Measured spin-barrier crossing cost (secs).
    pub sync_secs: f64,
}

/// Accumulates per-epoch measurements under one `(t_a, t_b, v_b)` split
/// and, once enough epochs are in, solves the §IV-F program using the
/// *measured* costs instead of the installation-time table: task A's
/// curve is the observed aggregate bandwidth rescaled along the
/// [`TierSim`] saturation shape, task B's is the observed per-update
/// time with the measured sync term swapped for the candidate V_B's.
pub struct AutoTuner {
    t_a: usize,
    t_b: usize,
    v_b: usize,
    warmup: usize,
    epochs: Vec<EpochMeasurement>,
    sync_secs: f64,
}

impl AutoTuner {
    /// `t_a`/`t_b`/`v_b` are the split the observed epochs run under;
    /// `warmup` is how many epochs to observe before recommending.
    pub fn new(t_a: usize, t_b: usize, v_b: usize, warmup: usize) -> Self {
        AutoTuner {
            t_a: t_a.max(1),
            t_b: t_b.max(1),
            v_b: v_b.max(1),
            warmup: warmup.max(1),
            epochs: Vec::new(),
            sync_secs: measure_sync_secs(),
        }
    }

    /// Record one epoch's observation.
    pub fn observe(&mut self, m: EpochMeasurement) {
        self.epochs.push(m);
    }

    /// True once `warmup` epochs have been observed.
    pub fn ready(&self) -> bool {
        self.epochs.len() >= self.warmup
    }

    /// Number of epochs observed so far.
    pub fn observed(&self) -> usize {
        self.epochs.len()
    }

    /// Distill the observations; `None` until both tasks have done real
    /// work under real traffic (all-zero counters cannot calibrate).
    pub fn measured(&self) -> Option<MeasuredCosts> {
        let mut secs = 0.0f64;
        let (mut a_up, mut b_up, mut slow, mut fast) = (0u64, 0u64, 0u64, 0u64);
        for e in &self.epochs {
            secs += e.run_secs;
            a_up += e.a_updates;
            b_up += e.b_updates;
            slow += e.slow_read_bytes;
            fast += e.fast_read_bytes;
        }
        if a_up == 0 || b_up == 0 || slow == 0 || secs <= 0.0 {
            return None;
        }
        Some(MeasuredCosts {
            a_bytes_per_update: slow as f64 / a_up as f64,
            agg_slow_gbs: slow as f64 / secs / 1e9,
            b_bytes_per_update: fast as f64 / b_up as f64,
            b_update_secs: secs / b_up as f64,
            sync_secs: self.sync_secs,
        })
    }

    /// Solve the §IV-F program over the measured costs: minimize
    /// `m * t_B(T_B, V_B)` subject to task A refreshing at least
    /// `r_tilde * n` gaps per epoch, `T_A + T_B * V_B <= thread_budget`.
    /// `sim` supplies the saturation shapes used to extrapolate away
    /// from the observed thread counts.
    pub fn recommend(
        &self,
        sim: &TierSim,
        n: usize,
        r_tilde: f64,
        fracs: &[f64],
        thread_budget: usize,
    ) -> Option<Recommendation> {
        let c = self.measured()?;
        let budget = thread_budget.max(2);

        // Task A: per-update time at T threads.  Aggregate bandwidth is
        // the *observed* figure rescaled along the saturation curve, so
        // a_updates(epoch, T) = epoch * agg_bw(T) / bytes_per_update.
        let base_gbs = sim.effective_gbs(Tier::Slow, self.t_a).max(1e-12);
        let ta_secs = |t: usize| -> f64 {
            let agg = c.agg_slow_gbs * sim.effective_gbs(Tier::Slow, t) / base_gbs;
            c.a_bytes_per_update * t as f64 / (agg.max(1e-12) * 1e9)
        };

        // Task B: strip the observed split's sync term to get one
        // lane's worth of work, then re-dress candidate (T_B, V_B)'s.
        let sync_term =
            |v: usize| if v > 1 { 3.0 * c.sync_secs * v as f64 } else { 0.0 };
        let w_obs = c.b_update_secs * self.t_b as f64;
        let w1 = ((w_obs - sync_term(self.v_b)) * self.v_b as f64).max(1e-12);
        let tb_secs = |t_b: usize, v_b: usize| -> f64 {
            let work = (w1 / v_b as f64 + sync_term(v_b)) / t_b as f64;
            let bw_floor = c.b_bytes_per_update
                / (sim.effective_gbs(Tier::Fast, t_b * v_b).max(1e-12) * 1e9);
            work.max(bw_floor)
        };

        let cap = budget.min(32);
        let t_as: Vec<usize> = (1..=cap).collect();
        let t_bs: Vec<usize> = (1..=cap).collect();
        let v_bs: Vec<usize> =
            [1usize, 2, 4, 8].into_iter().filter(|&v| v < budget).collect();

        let mut best: Option<Recommendation> = None;
        for &frac in fracs {
            let m = ((n as f64 * frac).round() as usize).clamp(1, n);
            for &ta in &t_as {
                let a_secs = ta_secs(ta);
                for &tb in &t_bs {
                    for &vb in &v_bs {
                        if ta + tb * vb > budget {
                            continue;
                        }
                        let epoch = m as f64 * tb_secs(tb, vb);
                        let a_updates = epoch / a_secs * ta as f64;
                        if a_updates < r_tilde * n as f64 {
                            continue;
                        }
                        let cand = Recommendation {
                            m,
                            t_a: ta,
                            t_b: tb,
                            v_b: vb,
                            epoch_secs: epoch,
                            refresh_frac: (a_updates / n as f64).min(1.0),
                            tile_cols: tile_cols_for(n, ta),
                        };
                        if best.map_or(true, |b| cand.epoch_secs < b.epoch_secs) {
                            best = Some(cand);
                        }
                    }
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_model() -> PerfModel {
        PerfModel::calibrate(
            &[10_000, 100_000, 1_000_000],
            &[1, 4, 8, 16, 24, 32],
            &[1, 2, 4, 8, 16],
            &[1, 2, 4, 8],
        )
    }

    #[test]
    fn calibration_produces_full_table() {
        let m = small_model();
        assert_eq!(m.a_entries.len(), 3 * 6);
        assert_eq!(m.b_entries.len(), 3 * 5 * 4);
        assert!(m.per_elem_secs > 0.0 && m.per_elem_secs < 1e-6);
    }

    #[test]
    fn a_updates_saturate_with_threads_fig2_shape() {
        // per-update time should stop improving once DRAM saturates
        let m = small_model();
        let t1 = m.t_a(1_000_000, 1).unwrap();
        let t16 = m.t_a(1_000_000, 16).unwrap();
        let t32 = m.t_a(1_000_000, 32).unwrap();
        // more threads don't make a *single* update faster once
        // bandwidth-bound; aggregate throughput is what scales.
        assert!(t16 <= t1 * 1.01);
        assert!(t32 >= t16 * 0.99, "past saturation no gains: {t32} vs {t16}");
    }

    #[test]
    fn v_b_split_pays_only_for_long_vectors_fig3_shape() {
        let m = small_model();
        // short vectors: V_B = 1 wins (sync overhead dominates)
        let short_1 = m.t_b(10_000, 4, 1).unwrap();
        let short_8 = m.t_b(10_000, 4, 8).unwrap();
        assert!(short_1 < short_8, "short d: V_B=1 best ({short_1} vs {short_8})");
        // long vectors: splitting wins
        let long_1 = m.t_b(1_000_000, 4, 1).unwrap();
        let long_8 = m.t_b(1_000_000, 4, 8).unwrap();
        assert!(long_8 < long_1, "long d: V_B=8 best ({long_8} vs {long_1})");
    }

    #[test]
    fn recommend_respects_constraint_and_budget() {
        let m = small_model();
        let rec = m
            .recommend(100_000, 100_000, 0.15, &[0.02, 0.05, 0.1, 0.25], 72)
            .expect("feasible configuration exists");
        assert!(rec.t_a + rec.t_b * rec.v_b <= 72);
        assert!(rec.refresh_frac >= 0.15 - 1e-9);
        assert!(rec.epoch_secs > 0.0);
    }

    #[test]
    fn infeasible_when_budget_too_small() {
        let m = small_model();
        // thread budget 1 cannot host both tasks (t_a >= 1 and t_b >= 1)
        assert!(m.recommend(1000, 10_000, 0.15, &[0.1], 1).is_none());
    }

    #[test]
    fn smaller_batch_fracs_win_when_feasible() {
        // minimizing m * t_B favors the smallest feasible m
        let m = small_model();
        let rec = m
            .recommend(10_000, 100_000, 0.05, &[0.02, 0.5], 72)
            .unwrap();
        assert_eq!(rec.m, 200, "should pick the small batch");
    }

    #[test]
    fn tile_cols_is_block_aligned_and_floored() {
        let b = crate::kernels::BLOCK_COLS;
        assert_eq!(tile_cols_for(10, 4), b, "tiny shards floor at one block");
        let big = tile_cols_for(1_000_000, 4);
        assert_eq!(big % b, 0, "aligned to BLOCK_COLS");
        assert!(big >= b);
        // ~64 tiles per shard: 250k/64 ~ 3906, rounded down to a block
        assert!(big <= 250_000 / 64 && big > 250_000 / 64 - b);
        assert_eq!(tile_cols_for(0, 0), b, "degenerate inputs stay sane");
    }

    #[test]
    fn autotuner_waits_for_warmup_and_real_counters() {
        let mut t = AutoTuner::new(2, 2, 1, 2);
        assert!(!t.ready());
        // all-zero observations can never calibrate
        t.observe(EpochMeasurement::default());
        t.observe(EpochMeasurement::default());
        assert!(t.ready());
        assert!(t.measured().is_none(), "zero counters cannot calibrate");
        let sim = TierSim::default();
        assert!(t.recommend(&sim, 1000, 0.15, &[0.1], 8).is_none());
    }

    #[test]
    fn autotuner_recommends_from_measured_counters() {
        let mut t = AutoTuner::new(2, 2, 1, 1);
        // synthetic but self-consistent epoch: 1s wall, A streamed 8 GB
        // over 100k refreshes (80 KB/refresh), B did 50k updates over
        // 2 GB of fast-tier traffic.
        t.observe(EpochMeasurement {
            run_secs: 1.0,
            a_updates: 100_000,
            b_updates: 50_000,
            slow_read_bytes: 8 << 30,
            fast_read_bytes: 2 << 30,
        });
        assert!(t.ready());
        let c = t.measured().expect("nonzero counters calibrate");
        assert!((c.a_bytes_per_update - (8u64 << 30) as f64 / 1e5).abs() < 1.0);
        assert!(c.agg_slow_gbs > 0.0);
        assert!(c.b_update_secs > 0.0 && c.sync_secs > 0.0);

        let sim = TierSim::default();
        let rec = t
            .recommend(&sim, 100_000, 0.15, &[0.02, 0.05, 0.1, 0.25], 16)
            .expect("feasible under a 16-thread budget");
        assert!(rec.t_a >= 1 && rec.t_b >= 1 && rec.v_b >= 1);
        assert!(rec.t_a + rec.t_b * rec.v_b <= 16, "budget respected");
        assert!(rec.refresh_frac >= 0.15 - 1e-9, "staleness constraint holds");
        assert!(rec.epoch_secs > 0.0);
        assert_eq!(rec.tile_cols % crate::kernels::BLOCK_COLS, 0);
        assert_eq!(rec.tile_cols, tile_cols_for(100_000, rec.t_a));
    }

    #[test]
    fn autotuner_extrapolates_more_a_threads_along_saturation_curve() {
        // starve A in the observation (tiny refresh rate): the
        // recommendation must raise t_a above the observed 1 to meet
        // the constraint, which only works if the saturation-curve
        // extrapolation credits extra threads with more bandwidth.
        let mut t = AutoTuner::new(1, 2, 1, 1);
        t.observe(EpochMeasurement {
            run_secs: 1.0,
            a_updates: 1_000,
            b_updates: 200_000,
            slow_read_bytes: 1 << 28,
            fast_read_bytes: 1 << 30,
        });
        let sim = TierSim::default();
        if let Some(rec) = t.recommend(&sim, 1_000_000, 0.15, &[0.25], 32) {
            assert!(rec.t_a > 1, "starved A needs more threads: {rec:?}");
        }
    }
}
