//! The §IV-F performance model.
//!
//! `t_{I,d}(threads)` — the time of a single coordinate update on task
//! `I` for vector length `d` — "is not trivial to derive [...] thus we
//! precompute the values for different thread setups and d during
//! installation and store them in a table."  [`PerfModel::calibrate`]
//! is that installation step (micro-benchmarks on synthetic data), and
//! [`PerfModel::recommend`] solves the paper's optimization:
//!
//! ```text
//! min_{m, T_A, T_B, V_B}  m * t_B,d(T_B, V_B)
//!     s.t.  m * t_B,d(T_B, V_B) / t_A,d(T_A)  >=  r~ * n
//! ```
//!
//! i.e. pick the fastest-B configuration whose epoch still leaves task A
//! enough time to refresh at least `r~` (~15%) of the gap memory.
//!
//! On this 1-core host the measured table cannot exhibit parallel
//! scaling, so calibration composes a *measured* single-thread
//! per-element cost with the [`TierSim`] bandwidth model (Fig. 2/3
//! shapes: near-linear until channel saturation, decline beyond; B's
//! extra V_B synchronization overhead grows with lanes).  Both the
//! measured constant and the modeled curve are reported.

use crate::memory::{Tier, TierSim};
use crate::util::Timer;

/// One table row: seconds per coordinate update.
#[derive(Clone, Copy, Debug)]
pub struct Entry {
    pub d: usize,
    pub threads: usize,   // T_A (task A) or T_B (task B)
    pub v_threads: usize, // V_B; 1 for task A
    pub secs_per_update: f64,
}

/// Recommendation from the model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Recommendation {
    pub m: usize,
    pub t_a: usize,
    pub t_b: usize,
    pub v_b: usize,
    /// Modeled epoch time (seconds).
    pub epoch_secs: f64,
    /// Modeled fraction of z refreshed per epoch.
    pub refresh_frac: f64,
}

/// The calibrated table.
pub struct PerfModel {
    pub a_entries: Vec<Entry>,
    pub b_entries: Vec<Entry>,
    /// Measured single-thread per-element dot cost (secs/element).
    pub per_elem_secs: f64,
    /// V_B synchronization cost per barrier crossing (secs).
    pub sync_secs: f64,
}

/// Per-update work in bytes for vector length d (col read + v touch).
fn update_bytes(d: usize) -> u64 {
    (d * 4 * 2) as u64
}

// --- KNL calibration constants for the *modeled* curves -----------------
// The modeled table reproduces the paper's machine (not this host):
// 72 cores @ 1.5 GHz, DRAM ~80 GB/s, MCDRAM ~440 GB/s.

/// Per-core flops/cycle of task A's gap sweep on KNL.  Derived from
/// Fig. 2: aggregate ~10 flops/cycle at the ~20-thread DRAM saturation
/// point -> ~0.5 per core.
pub const KNL_A_CORE_FPC: f64 = 0.5;

/// Whole-coordinate-update flops/cycle on KNL (paper §IV-A3: "our
/// entire coordinate update achieves about 7.2 flops/cycle").
pub const KNL_B_FPC: f64 = 7.2;

/// Counter-barrier crossing cost on KNL (mutex-protected counters over
/// a handful of threads; calibrated so the V_B crossover lands at the
/// paper's d ~ 130k, Fig. 3).
pub const KNL_SYNC_SECS: f64 = 2.7e-6;

impl PerfModel {
    /// Measure the host constants and build the table for the given
    /// vector lengths and thread counts.
    pub fn calibrate(ds: &[usize], t_as: &[usize], t_bs: &[usize], v_bs: &[usize]) -> Self {
        // Measure single-thread per-element dot cost on a warm buffer.
        let d_probe = 1 << 16;
        let x = vec![1.000_1f32; d_probe];
        let w = vec![0.999_9f32; d_probe];
        let mut acc = 0.0f32;
        let (secs, _) = crate::util::timer::bench_median(
            || {
                acc += crate::kernels::dot(&x, &w);
            },
            0.05,
            200,
        );
        std::hint::black_box(acc);
        let per_elem_secs = secs / d_probe as f64;

        // Measure spin-barrier crossing cost with 2 real participants —
        // this is the per-barrier price V_B pays (3 crossings/update).
        let sync_secs = {
            let b = crate::threadpool::SpinBarrier::new(2);
            let rounds = 2000;
            let t = Timer::start();
            std::thread::scope(|s| {
                for _ in 0..2 {
                    s.spawn(|| {
                        for _ in 0..rounds {
                            b.wait();
                        }
                    });
                }
            });
            t.secs() / rounds as f64
        };

        let mut model = PerfModel {
            a_entries: Vec::new(),
            b_entries: Vec::new(),
            per_elem_secs,
            sync_secs,
        };
        let sim = TierSim::default();
        for &d in ds {
            for &ta in t_as {
                model.a_entries.push(Entry {
                    d,
                    threads: ta,
                    v_threads: 1,
                    secs_per_update: model.modeled_a_update(&sim, d, ta),
                });
            }
            for &tb in t_bs {
                for &vb in v_bs {
                    model.b_entries.push(Entry {
                        d,
                        threads: tb,
                        v_threads: vb,
                        secs_per_update: model.modeled_b_update(&sim, d, tb, vb),
                    });
                }
            }
        }
        model
    }

    /// Modeled time of one task-A update (gap refresh) at T_A threads on
    /// the paper's KNL: each of the T_A concurrent streamers gets a
    /// 1/T_A share of the (saturating) DRAM bandwidth, floored by the
    /// per-core compute rate.  Aggregate throughput therefore follows
    /// Fig. 2: near-linear to ~20 threads, flat to 24, declining after.
    pub fn modeled_a_update(&self, sim: &TierSim, d: usize, t_a: usize) -> f64 {
        let per_thread_gbs = sim.effective_gbs(Tier::Slow, t_a) / t_a.max(1) as f64;
        let bw_secs = update_bytes(d) as f64 / (per_thread_gbs * 1e9);
        // 2d flops at the per-core rate:
        let compute_secs =
            2.0 * d as f64 / (KNL_A_CORE_FPC * crate::util::timer::KNL_HZ);
        bw_secs.max(compute_secs)
    }

    /// Modeled time of one task-B update at (T_B, V_B) on KNL: MCDRAM is
    /// hard to saturate (the paper's VTune finding: L2-per-tile is the
    /// bottleneck, bandwidth headroom remains), so the compute rate of
    /// 7.2 flops/cycle per update dominates; V_B splits the vector but
    /// pays 3 barrier crossings per update across its lanes (§IV-B),
    /// which is why V_B > 1 only pays off for very long vectors (Fig 3).
    pub fn modeled_b_update(&self, sim: &TierSim, d: usize, t_b: usize, v_b: usize) -> f64 {
        let streams = t_b * v_b;
        let per_stream_gbs = sim.effective_gbs(Tier::Fast, streams) / streams as f64;
        // dot + axpy stream the column twice (v stays L2-resident per
        // the §IV-A2 chunk sizing); each of the V_B lanes moves 1/V_B:
        let bw_secs =
            2.0 * update_bytes(d) as f64 / (per_stream_gbs * 1e9 * v_b as f64);
        // 4d flops per update at 7.2 f/c, split across V_B lanes:
        let compute_secs =
            4.0 * d as f64 / (KNL_B_FPC * crate::util::timer::KNL_HZ * v_b as f64);
        let sync = if v_b > 1 { 3.0 * KNL_SYNC_SECS * v_b as f64 } else { 0.0 };
        // chunk-lock contention grows mildly with concurrent writers
        let lock = 2e-7 * (t_b.saturating_sub(1)) as f64;
        compute_secs.max(bw_secs) + sync + lock
    }

    fn lookup(entries: &[Entry], d: usize, threads: usize, v_threads: usize) -> Option<f64> {
        // nearest-d row with exact thread match
        entries
            .iter()
            .filter(|e| e.threads == threads && e.v_threads == v_threads)
            .min_by_key(|e| e.d.abs_diff(d))
            .map(|e| e.secs_per_update)
    }

    pub fn t_a(&self, d: usize, threads: usize) -> Option<f64> {
        Self::lookup(&self.a_entries, d, threads, 1)
    }

    pub fn t_b(&self, d: usize, t_b: usize, v_b: usize) -> Option<f64> {
        Self::lookup(&self.b_entries, d, t_b, v_b)
    }

    /// Solve the §IV-F program by enumeration over the table, for a
    /// problem with `n` coordinates of length `d`, staleness target
    /// `r_tilde`, batch-size candidates `fracs`, and a total thread
    /// budget (T_A + T_B * V_B <= budget).
    pub fn recommend(
        &self,
        n: usize,
        d: usize,
        r_tilde: f64,
        fracs: &[f64],
        thread_budget: usize,
    ) -> Option<Recommendation> {
        let mut best: Option<Recommendation> = None;
        let t_as: Vec<usize> = dedup_sorted(self.a_entries.iter().map(|e| e.threads));
        let t_bs: Vec<usize> = dedup_sorted(self.b_entries.iter().map(|e| e.threads));
        let v_bs: Vec<usize> = dedup_sorted(self.b_entries.iter().map(|e| e.v_threads));
        for &frac in fracs {
            let m = ((n as f64 * frac).round() as usize).clamp(1, n);
            for &ta in &t_as {
                let Some(ta_secs) = self.t_a(d, ta) else { continue };
                for &tb in &t_bs {
                    for &vb in &v_bs {
                        if ta + tb * vb > thread_budget {
                            continue;
                        }
                        let Some(tb_secs) = self.t_b(d, tb, vb) else { continue };
                        let epoch = m as f64 * tb_secs;
                        // A updates during the epoch, across T_A threads:
                        let a_updates = epoch / ta_secs * ta as f64;
                        let refresh = (a_updates / n as f64).min(1.0);
                        if a_updates < r_tilde * n as f64 {
                            continue; // constraint violated
                        }
                        let cand = Recommendation {
                            m,
                            t_a: ta,
                            t_b: tb,
                            v_b: vb,
                            epoch_secs: epoch,
                            refresh_frac: refresh,
                        };
                        if best.map_or(true, |b| cand.epoch_secs < b.epoch_secs) {
                            best = Some(cand);
                        }
                    }
                }
            }
        }
        best
    }
}

fn dedup_sorted(it: impl Iterator<Item = usize>) -> Vec<usize> {
    let mut v: Vec<usize> = it.collect();
    v.sort_unstable();
    v.dedup();
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_model() -> PerfModel {
        PerfModel::calibrate(
            &[10_000, 100_000, 1_000_000],
            &[1, 4, 8, 16, 24, 32],
            &[1, 2, 4, 8, 16],
            &[1, 2, 4, 8],
        )
    }

    #[test]
    fn calibration_produces_full_table() {
        let m = small_model();
        assert_eq!(m.a_entries.len(), 3 * 6);
        assert_eq!(m.b_entries.len(), 3 * 5 * 4);
        assert!(m.per_elem_secs > 0.0 && m.per_elem_secs < 1e-6);
    }

    #[test]
    fn a_updates_saturate_with_threads_fig2_shape() {
        // per-update time should stop improving once DRAM saturates
        let m = small_model();
        let t1 = m.t_a(1_000_000, 1).unwrap();
        let t16 = m.t_a(1_000_000, 16).unwrap();
        let t32 = m.t_a(1_000_000, 32).unwrap();
        // more threads don't make a *single* update faster once
        // bandwidth-bound; aggregate throughput is what scales.
        assert!(t16 <= t1 * 1.01);
        assert!(t32 >= t16 * 0.99, "past saturation no gains: {t32} vs {t16}");
    }

    #[test]
    fn v_b_split_pays_only_for_long_vectors_fig3_shape() {
        let m = small_model();
        // short vectors: V_B = 1 wins (sync overhead dominates)
        let short_1 = m.t_b(10_000, 4, 1).unwrap();
        let short_8 = m.t_b(10_000, 4, 8).unwrap();
        assert!(short_1 < short_8, "short d: V_B=1 best ({short_1} vs {short_8})");
        // long vectors: splitting wins
        let long_1 = m.t_b(1_000_000, 4, 1).unwrap();
        let long_8 = m.t_b(1_000_000, 4, 8).unwrap();
        assert!(long_8 < long_1, "long d: V_B=8 best ({long_8} vs {long_1})");
    }

    #[test]
    fn recommend_respects_constraint_and_budget() {
        let m = small_model();
        let rec = m
            .recommend(100_000, 100_000, 0.15, &[0.02, 0.05, 0.1, 0.25], 72)
            .expect("feasible configuration exists");
        assert!(rec.t_a + rec.t_b * rec.v_b <= 72);
        assert!(rec.refresh_frac >= 0.15 - 1e-9);
        assert!(rec.epoch_secs > 0.0);
    }

    #[test]
    fn infeasible_when_budget_too_small() {
        let m = small_model();
        // thread budget 1 cannot host both tasks (t_a >= 1 and t_b >= 1)
        assert!(m.recommend(1000, 10_000, 0.15, &[0.1], 1).is_none());
    }

    #[test]
    fn smaller_batch_fracs_win_when_feasible() {
        // minimizing m * t_B favors the smallest feasible m
        let m = small_model();
        let rec = m
            .recommend(10_000, 100_000, 0.05, &[0.02, 0.5], 72)
            .unwrap();
        assert_eq!(rec.m, 200, "should pick the small batch");
    }
}
