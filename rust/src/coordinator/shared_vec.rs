//! The shared vector `v = D alpha` with medium-grained locking
//! (paper §IV-C).
//!
//! pthreads has no atomics, so the paper locks *chunks* of 1024
//! elements with mutexes — coarse enough to amortize lock cost over a
//! dense column segment, fine enough to keep contention low.  We do the
//! same: writes take chunk mutexes; reads are lock-free relaxed atomic
//! loads (asynchronous SCD reads stale values by design — Hsieh et al.
//! [16] give the convergence guarantees HTHC relies on, *provided*
//! updates themselves are not lost, which the locks ensure).
//!
//! Storage is `AtomicU32` bit-cast to f32 so that racy reads are
//! well-defined in rust (on x86 a relaxed load is an ordinary `mov`).
//!
//! The lock-free inner bodies (mapped dots, unlocked axpy segments)
//! live in [`crate::kernels`]; this module owns the chunk-lock
//! discipline and hands the kernels the ranges each lock covers.

use crate::kernels;
// Data plane: the bit cells stay raw `std` atomics in every build —
// their races are by-design HOGWILD word-atomic reads/writes (module
// docs), and the atomic-slice kernels take `&[raw::AtomicU32]`.
use crate::sync::raw::AtomicU32;
use crate::sync::{Mutex, Ordering};

pub struct SharedVector {
    /// f32 bit cells.  Relaxed everywhere: stale reads are the
    /// algorithm's contract (Hsieh et al.); lost *updates* are ruled
    /// out by the chunk locks, not by ordering.
    bits: Vec<AtomicU32>,
    locks: Vec<Mutex<()>>,
    chunk: usize,
}

impl SharedVector {
    pub fn new(len: usize, lock_chunk: usize) -> Self {
        assert!(lock_chunk >= 1);
        let n_locks = len.div_ceil(lock_chunk).max(1);
        SharedVector {
            bits: (0..len).map(|_| AtomicU32::new(0)).collect(),
            locks: (0..n_locks).map(|_| Mutex::new(())).collect(),
            chunk: lock_chunk,
        }
    }

    pub fn from_slice(v: &[f32], lock_chunk: usize) -> Self {
        let s = Self::new(v.len(), lock_chunk);
        for (slot, &x) in s.bits.iter().zip(v) {
            slot.store(x.to_bits(), Ordering::Relaxed);
        }
        s
    }

    pub fn len(&self) -> usize {
        self.bits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bits.is_empty()
    }

    pub fn n_locks(&self) -> usize {
        self.locks.len()
    }

    /// Lock-free (stale-tolerant) read.
    #[inline(always)]
    pub fn read(&self, i: usize) -> f32 {
        f32::from_bits(self.bits[i].load(Ordering::Relaxed))
    }

    /// Plain (unlocked) store — used for `alpha`, whose coordinates are
    /// each owned by exactly one updater within an epoch.
    #[inline(always)]
    pub fn write(&self, i: usize, x: f32) {
        self.bits[i].store(x.to_bits(), Ordering::Relaxed);
    }

    /// Copy the whole vector (epoch-boundary snapshot for task A).
    pub fn snapshot(&self) -> Vec<f32> {
        self.bits
            .iter()
            .map(|b| f32::from_bits(b.load(Ordering::Relaxed)))
            .collect()
    }

    /// Overwrite the whole vector (initialization / tests).
    pub fn store_all(&self, v: &[f32]) {
        assert_eq!(v.len(), self.len());
        for (slot, &x) in self.bits.iter().zip(v) {
            slot.store(x.to_bits(), Ordering::Relaxed);
        }
    }

    /// `v[rows] += delta * vals` for a sparse column segment, taking each
    /// chunk lock once (paper: lock cost amortized over the chunk;
    /// entries must be row-sorted, which CSC columns are).
    pub fn axpy_sparse_locked(&self, rows: &[u32], vals: &[f32], delta: f32) {
        let mut i = 0;
        while i < rows.len() {
            let chunk_id = rows[i] as usize / self.chunk;
            let chunk_end = ((chunk_id + 1) * self.chunk) as u32;
            // entries are row-sorted: the lock's segment is contiguous
            let seg = i + rows[i..].partition_point(|&r| r < chunk_end);
            let _guard = self.locks[chunk_id].lock().unwrap_or_else(|e| e.into_inner());
            kernels::sparse_axpy_atomic(&self.bits, &rows[i..seg], &vals[i..seg], delta);
            i = seg;
        }
    }

    /// `v[lo..hi] += delta * x[lo..hi]` for a dense column range under
    /// the covering chunk locks.
    pub fn axpy_dense_locked(&self, x: &[f32], delta: f32, lo: usize, hi: usize) {
        debug_assert!(hi <= self.len() && x.len() >= hi);
        let mut i = lo;
        while i < hi {
            let chunk_id = i / self.chunk;
            let chunk_end = ((chunk_id + 1) * self.chunk).min(hi);
            let _guard = self.locks[chunk_id].lock().unwrap_or_else(|e| e.into_inner());
            kernels::axpy_atomic(&self.bits, x, delta, i, chunk_end);
            i = chunk_end;
        }
    }

    /// Per-element atomic add via CAS — PASSCoDe-atomic / OMP `atomic`
    /// semantics (used by the baselines, not by HTHC itself).
    #[inline]
    pub fn add_atomic(&self, i: usize, x: f32) {
        let slot = &self.bits[i];
        let mut cur = slot.load(Ordering::Relaxed);
        loop {
            let new = (f32::from_bits(cur) + x).to_bits();
            match slot.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Non-atomic read-modify-write (racy; lost updates possible) — the
    /// OMP-WILD / PASSCoDe-wild semantics.  Each access is individually
    /// a relaxed atomic so behaviour is defined, but the composition is
    /// deliberately not.
    #[inline]
    pub fn add_wild(&self, i: usize, x: f32) {
        let old = f32::from_bits(self.bits[i].load(Ordering::Relaxed));
        self.bits[i].store((old + x).to_bits(), Ordering::Relaxed);
    }

    /// Fused stale dot: `sum_r x[r] * w_of(v[r], y[r])` over `[lo, hi)`.
    /// This is task B's hot read path — it must see *recent* v (not the
    /// epoch snapshot), so it streams the live atomics
    /// ([`kernels::dot_mapped_atomic`] carries the §Perf history).
    #[inline]
    pub fn dot_mapped_range<W: Fn(f32, f32) -> f32>(
        &self,
        x: &[f32],
        y: &[f32],
        w_of: W,
        lo: usize,
        hi: usize,
    ) -> f32 {
        kernels::dot_mapped_atomic(&self.bits, x, y, w_of, lo, hi)
    }

    /// Scaled plain dot `scale * sum_r x[r] * v[r]` over `[lo, hi)` —
    /// the y-free fast path for models with `w = scale * v` (SVM family).
    #[inline]
    pub fn dot_scaled_range(&self, x: &[f32], scale: f32, lo: usize, hi: usize) -> f32 {
        kernels::dot_scaled_atomic(&self.bits, x, scale, lo, hi)
    }

    /// Sparse variant of [`Self::dot_mapped_range`].
    #[inline]
    pub fn dot_mapped_sparse<W: Fn(f32, f32) -> f32>(
        &self,
        rows: &[u32],
        vals: &[f32],
        y: &[f32],
        w_of: W,
    ) -> f32 {
        kernels::sparse_dot_mapped_atomic(&self.bits, rows, vals, y, w_of)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_snapshot() {
        let v = SharedVector::from_slice(&[1.0, -2.5, 3.25], 2);
        assert_eq!(v.read(1), -2.5);
        assert_eq!(v.snapshot(), vec![1.0, -2.5, 3.25]);
        assert_eq!(v.n_locks(), 2);
    }

    #[test]
    fn axpy_dense_locked_basic() {
        let v = SharedVector::from_slice(&[0.0; 10], 4);
        let x: Vec<f32> = (0..10).map(|i| i as f32).collect();
        v.axpy_dense_locked(&x, 2.0, 0, 10);
        for i in 0..10 {
            assert_eq!(v.read(i), 2.0 * i as f32);
        }
        // partial range
        v.axpy_dense_locked(&x, 1.0, 3, 7);
        assert_eq!(v.read(2), 4.0);
        assert_eq!(v.read(3), 9.0);
        assert_eq!(v.read(6), 18.0);
        assert_eq!(v.read(7), 14.0);
    }

    #[test]
    fn axpy_sparse_locked_basic() {
        let v = SharedVector::from_slice(&[1.0; 8], 3);
        v.axpy_sparse_locked(&[0, 2, 5, 7], &[1.0, 2.0, 3.0, 4.0], 0.5);
        assert_eq!(v.read(0), 1.5);
        assert_eq!(v.read(2), 2.0);
        assert_eq!(v.read(5), 2.5);
        assert_eq!(v.read(7), 3.0);
        assert_eq!(v.read(1), 1.0);
    }

    #[test]
    fn locked_axpy_loses_no_updates_under_contention() {
        // The §IV-C invariant: with chunk locks, concurrent v updates
        // must all land (unlike add_wild).
        let n = 256;
        let v = SharedVector::new(n, 64);
        let x = vec![1.0f32; n];
        let threads = 8;
        let reps = 100;
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| {
                    for _ in 0..reps {
                        v.axpy_dense_locked(&x, 1.0, 0, n);
                    }
                });
            }
        });
        for i in 0..n {
            assert_eq!(v.read(i), (threads * reps) as f32);
        }
    }

    #[test]
    fn atomic_add_loses_no_updates() {
        let v = SharedVector::new(4, 1024);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        v.add_atomic(2, 1.0);
                    }
                });
            }
        });
        assert_eq!(v.read(2), 8000.0);
    }

    #[test]
    fn dot_mapped_range_identity_map() {
        let v = SharedVector::from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0], 1024);
        let x = vec![1.0f32; 5];
        let y = vec![0.0f32; 5];
        let got = v.dot_mapped_range(&x, &y, |vj, yj| vj - yj, 0, 5);
        assert_eq!(got, 15.0);
        let part = v.dot_mapped_range(&x, &y, |vj, yj| vj - yj, 1, 4);
        assert_eq!(part, 9.0);
    }

    #[test]
    fn dot_mapped_sparse_matches() {
        let v = SharedVector::from_slice(&[1.0, 2.0, 3.0, 4.0], 1024);
        let y = vec![0.5f32; 4];
        let got = v.dot_mapped_sparse(&[1, 3], &[2.0, -1.0], &y, |vj, yj| vj * yj);
        assert_eq!(got, 2.0 * 1.0 - 1.0 * 2.0);
    }
}
