//! The cluster facade: configuration, the tick loop, and the report.
//!
//! [`run_cluster`] is a pure function of `(dataset, model factory,
//! ClusterConfig)` — one real thread steps the virtual network and the
//! nodes in id order, so every run is reproducible from the seed.  The
//! result wraps a standard [`FitReport`] (solver `"cluster"`, the
//! leader's certified trace, `cluster_*` extras) so downstream tooling
//! — `report.summary()`, `epoch_to_gap`, the bench convergence axis —
//! treats cluster runs like any single-node engine.

use super::net::{FaultPlan, NetStats, Network};
use super::node::Node;
use super::NodeId;
use crate::bail;
use crate::data::Dataset;
use crate::glm::GlmModel;
use crate::solver::{keys, Extras, FitReport};
use crate::util::Timer;

/// Protocol timeouts, in virtual ticks.  Defaults keep the implied
/// ordering the protocol relies on: base latency (1) < rto <
/// state/worker timeouts < election timeout, with headroom for fault
/// delays in between.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    /// Reliable-link retransmission interval (doubles up to a cap).
    pub rto: u64,
    /// Follower silence before it starts an election (per-id stagger
    /// of `7 * id` ticks is added on top).
    pub election_timeout: u64,
    /// How long a candidate waits for an `Alive` veto.
    pub alive_timeout: u64,
    /// Leader round stall before silent owners are declared dead.
    pub worker_timeout: u64,
    /// How long a fresh leader collects `State` replies.
    pub state_timeout: u64,
    /// Grace ticks after the leader finishes, so `Stop` reaches the
    /// other nodes before the loop exits.
    pub drain_ticks: u64,
}

impl Default for Timing {
    fn default() -> Self {
        Timing {
            rto: 8,
            election_timeout: 80,
            alive_timeout: 20,
            worker_timeout: 40,
            state_timeout: 30,
            drain_ticks: 200,
        }
    }
}

/// Configuration for one simulated cluster run.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Node (and shard) count `K`.
    pub nodes: usize,
    /// Local CD sweeps per round (CoCoA inner iterations).
    pub local_passes: usize,
    /// Stop once the exact duality gap falls below this.
    pub gap_tol: f64,
    /// Round budget per leader term.
    pub max_rounds: u64,
    /// Certificate cadence, in rounds.
    pub eval_every: u64,
    /// Seed for the fault plan's randomness (the only randomness).
    pub seed: u64,
    /// Hard virtual-time budget for the whole run.
    pub max_ticks: u64,
    /// Which node boots as coordinator.
    pub initial_leader: NodeId,
    pub fault: FaultPlan,
    pub timing: Timing,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 4,
            local_passes: 1,
            gap_tol: 1e-5,
            max_rounds: 200,
            eval_every: 1,
            seed: 42,
            max_ticks: 100_000,
            initial_leader: 0,
            fault: FaultPlan::default(),
            timing: Timing::default(),
        }
    }
}

/// Outcome of a cluster run.
pub struct ClusterReport {
    /// Standard fit report from the final leader: `alpha`, `v`, the
    /// certified trace (time column = virtual ticks), `cluster_*`
    /// extras.
    pub fit: FitReport,
    pub nodes: usize,
    pub final_leader: NodeId,
    /// Virtual ticks the run took.
    pub ticks: u64,
    /// Election attempts across all nodes.
    pub elections: u64,
    /// Leadership takeovers (0 when the bootstrap leader survives).
    pub failovers: u64,
    pub stats: NetStats,
}

impl ClusterReport {
    pub fn summary(&self) -> String {
        format!(
            "{} | nodes {} leader {} ticks {} elections {} failovers {} \
             sent {} dropped {} retx {}",
            self.fit.summary(),
            self.nodes,
            self.final_leader,
            self.ticks,
            self.elections,
            self.failovers,
            self.stats.sent,
            self.stats.dropped,
            self.stats.retransmits,
        )
    }
}

/// Run the simulated cluster to completion (convergence, round budget,
/// or tick budget).  `make_model` is called once per node plus once
/// for the certificate model, so every node owns identical model
/// state.
pub fn run_cluster(
    data: &Dataset,
    make_model: &dyn Fn() -> Box<dyn GlmModel>,
    cfg: &ClusterConfig,
) -> crate::Result<ClusterReport> {
    let k = cfg.nodes;
    if k == 0 {
        bail!("cluster: --nodes must be >= 1");
    }
    if cfg.initial_leader >= k {
        bail!("cluster: initial leader {} out of range (nodes {k})", cfg.initial_leader);
    }
    if data.n_cols() < k {
        bail!("cluster: {} nodes but only {} columns to shard", k, data.n_cols());
    }
    let timer = Timer::start();
    let mut net = Network::new(k, cfg.fault.clone(), cfg.seed);
    let mut nodes: Vec<Node<'_>> = (0..k).map(|i| Node::new(i, data, make_model(), cfg)).collect();
    nodes[cfg.initial_leader].bootstrap_leader();

    let mut drain_left: Option<u64> = None;
    loop {
        net.step();
        for i in 0..k {
            if net.is_alive(i) {
                nodes[i].step(&mut net);
            }
        }
        let any_finished_leader = nodes.iter().any(|n| n.is_finished_leader());
        if drain_left.is_none() && any_finished_leader {
            drain_left = Some(cfg.timing.drain_ticks);
        }
        if !any_finished_leader {
            // A split-brain heal can resume a "finished" half: the solo
            // leader that converged behind the partition gets deposed
            // by the higher-term survivor and rejoins as a worker.  The
            // drain must not time out mid-resumed-training.
            drain_left = None;
        }
        if let Some(left) = &mut drain_left {
            let all_done = (0..k).all(|i| !net.is_alive(i) || nodes[i].finished);
            if all_done || *left == 0 {
                break;
            }
            *left -= 1;
        }
        if net.now() >= cfg.max_ticks {
            break;
        }
    }

    // Report from the highest-authority leader (prefer finished ones).
    let pick = |finished_only: bool| -> Option<usize> {
        nodes
            .iter()
            .enumerate()
            .filter(|(i, n)| {
                n.is_leader()
                    && n.lead.is_some()
                    && (!finished_only || (n.finished && net.is_alive(*i)))
            })
            .max_by_key(|(i, n)| (n.term, *i))
            .map(|(i, _)| i)
    };
    let Some(leader_id) = pick(true).or_else(|| pick(false)) else {
        bail!("cluster: no surviving leader to report (all nodes dead?)");
    };

    let elections: u64 = nodes.iter().map(|n| n.elections).sum();
    let failovers: u64 = nodes.iter().map(|n| n.failovers).sum();
    let mut stats = net.stats;
    for n in &nodes {
        stats.retransmits += n.link.retransmits;
        stats.dedup_dropped += n.link.dedup_dropped;
    }
    let ticks = net.now();

    let leader = &nodes[leader_id];
    // PANIC-OK: pick() only returned nodes with lead.is_some().
    let ls = leader.lead.as_ref().expect("picked leader has state");
    let mut extras = Extras::default();
    extras.set_u64(keys::CLUSTER_NODES, k as u64);
    extras.set_u64(keys::CLUSTER_ROUNDS, ls.round);
    extras.set_u64(keys::CLUSTER_TICKS, ticks);
    extras.set_u64(keys::CLUSTER_ELECTIONS, elections);
    extras.set_u64(keys::CLUSTER_FAILOVERS, failovers);
    extras.set_u64(keys::CLUSTER_FINAL_LEADER, leader_id as u64);
    extras.set_u64(keys::CLUSTER_MSGS_SENT, stats.sent);
    extras.set_u64(keys::CLUSTER_MSGS_DROPPED, stats.dropped);
    extras.set_u64(keys::CLUSTER_MSGS_DUPLICATED, stats.duplicated);
    extras.set_u64(keys::CLUSTER_RETRANSMITS, stats.retransmits);
    extras.set_u64(keys::CLUSTER_DEDUP_DROPPED, stats.dedup_dropped);

    let fit = FitReport {
        solver: "cluster",
        alpha: ls.flat_alpha(),
        v: ls.v.clone(),
        trace: ls.trace.clone(),
        epochs: ls.round as usize,
        converged: leader.converged,
        wall_secs: timer.secs(),
        phase_times: Default::default(),
        staleness: Default::default(),
        extras,
    };
    Ok(ClusterReport {
        fit,
        nodes: k,
        final_leader: leader_id,
        ticks,
        elections,
        failovers,
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetKind, Family};
    use crate::glm::Lasso;

    fn tiny() -> Dataset {
        Dataset::generated(DatasetKind::Tiny, Family::Regression, 1.0, 77)
    }

    fn lasso() -> Box<dyn GlmModel> {
        Box::new(Lasso::new(0.3))
    }

    #[test]
    fn rejects_degenerate_configs() {
        let g = tiny();
        let bad = ClusterConfig { nodes: 0, ..Default::default() };
        assert!(run_cluster(&g, &lasso, &bad).is_err());
        let bad = ClusterConfig { nodes: 2, initial_leader: 2, ..Default::default() };
        assert!(run_cluster(&g, &lasso, &bad).is_err());
        let bad = ClusterConfig { nodes: g.n() + 1, ..Default::default() };
        assert!(run_cluster(&g, &lasso, &bad).is_err());
    }

    #[test]
    fn clean_two_node_run_converges_and_is_deterministic() {
        let g = tiny();
        let cfg = ClusterConfig { nodes: 2, gap_tol: 1e-3, max_rounds: 500, ..Default::default() };
        let a = run_cluster(&g, &lasso, &cfg).unwrap();
        let b = run_cluster(&g, &lasso, &cfg).unwrap();
        assert!(a.fit.converged, "{}", a.summary());
        assert_eq!(a.ticks, b.ticks);
        assert_eq!(a.fit.final_gap(), b.fit.final_gap());
        assert_eq!(a.fit.alpha, b.fit.alpha);
        assert_eq!(a.failovers, 0);
        assert_eq!(a.final_leader, 0);
        assert_eq!(a.fit.extras.u64(keys::CLUSTER_NODES), Some(2));
    }
}
