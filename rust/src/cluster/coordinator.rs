//! The cluster wire protocol and the coordinator's (leader's) state.
//!
//! # Protocol
//!
//! Training proceeds in **strictly sequential rounds** (the CoCoA
//! outer iteration).  The leader unicasts `Round { term, round, sigma,
//! v, shards }` to every node it believes live; the per-recipient
//! `shards` payload carries the *authoritative* dual variables for the
//! shards that node currently owns (empty for idle nodes — the Round
//! then just serves as a heartbeat).  A worker replaces its local
//! state with the payload, runs `local_passes` sigma-scaled coordinate
//! descent sweeps over its shard views starting from the broadcast
//! `v`, and replies `Delta { term, round, shards }` with the updated
//! duals.  The leader folds each Delta into its cache and the global
//! `v` (one `axpy` per moved coordinate), and only when **every**
//! waited-on owner has reported does it evaluate (re-anchor
//! `v = D alpha`, exact duality gap over the full dataset) and start
//! the next round.  Because the leader never starts round `r+1` before
//! folding all of round `r`, the invariant *broadcast `v` is exactly
//! `D` times the broadcast duals* holds on every round — every
//! reachable state is a valid primal-dual pair, so the certificate is
//! always sound no matter which failures occurred.
//!
//! `sigma` is the number of shard-owning nodes: scaling the curvature
//! term by `sigma` makes the "adding" aggregation safe (Ioannou et
//! al.), and degenerates to exact sequential CD at one owner.
//!
//! # Failure handling
//!
//! *Worker death*: if a round stalls past `worker_timeout`, the leader
//! declares the missing owners dead, hands their shards (with the
//! cached duals — no progress is lost) to responsive nodes, and starts
//! a fresh round.  Late Deltas for the abandoned round are ignored;
//! the next Round payload overwrites any diverged worker copy.
//!
//! *Leader death*: followers that stop hearing Rounds time out into a
//! bully election ([`super::node`]): `Election` goes to higher ids,
//! any of them answers `Alive`, an unanswered candidate becomes leader
//! and broadcasts `Coordinator { term }`.  Nodes adopt the leader with
//! the highest `(term, id)`, and reply `State` with their owned duals
//! (plus, for deposed leaders, their whole cache).  The new leader
//! collects States until `state_timeout`, resolves ownership (owned
//! claims beat cached copies beat zeros), re-anchors `v = D alpha`,
//! and resumes rounds.  Split-brain during a partition is tolerated:
//! both sides keep certified training, and on heal the higher
//! `(term, id)` leader wins while the other steps down and resyncs.

use std::collections::{BTreeMap, BTreeSet};

use super::{shard_cols, NodeId, Tick};
use crate::data::{ColumnOps, Dataset};
use crate::glm::{self, GlmModel};
use crate::metrics::ConvergenceTrace;

/// Application-layer messages (carried by [`super::net::Packet::Data`]).
#[derive(Clone, Debug)]
pub enum Message {
    /// Leader -> node: start round `round`; `shards` are the duals the
    /// recipient owns (authoritative), `v` the shared vector they are
    /// consistent with, `sigma` the curvature scale.
    Round { term: u64, round: u64, sigma: f32, v: Vec<f32>, shards: Vec<(usize, Vec<f32>)> },
    /// Node -> leader: the updated duals after the local passes.
    Delta { term: u64, round: u64, shards: Vec<(usize, Vec<f32>)> },
    /// Leader -> all: training is over (converged or round budget hit).
    Stop { term: u64, round: u64, gap: f64, converged: bool },
    /// Bully election probe, sent to higher ids only.
    Election { term: u64 },
    /// "I outrank you and I'm alive" — demotes the probing candidate.
    Alive { term: u64 },
    /// New-leader announcement; doubles as a state request.
    Coordinator { term: u64 },
    /// Reply to `Coordinator`: `owned` are the sender's live shards,
    /// `cached` a deposed leader's full cache (lower priority).
    State { term: u64, owned: Vec<(usize, Vec<f32>)>, cached: Vec<(usize, Vec<f32>)> },
}

/// Post-election resync: States collected until the deadline.
#[derive(Debug)]
pub struct Collect {
    pub deadline: Tick,
    pub reported: BTreeSet<NodeId>,
    owned: BTreeMap<usize, (NodeId, Vec<f32>)>,
    cached: BTreeMap<usize, (NodeId, Vec<f32>)>,
}

/// Everything the current leader tracks: the dual cache (one entry per
/// shard), the shared vector consistent with it, shard ownership,
/// round bookkeeping, and the convergence trace whose gap column *is*
/// the certificate.
#[derive(Debug)]
pub struct LeaderState {
    pub term: u64,
    /// Rounds completed or in flight under this leader (1-based).
    pub round: u64,
    /// `owners[s]` = node currently responsible for shard `s`.
    pub owners: Vec<NodeId>,
    /// Authoritative duals per shard; `v` is always `D` times their
    /// concatenation (exactly at eval rounds, to fp32 drift between).
    pub alpha: Vec<Vec<f32>>,
    pub v: Vec<f32>,
    /// Owners the current round still waits on.
    pub waiting: BTreeSet<NodeId>,
    /// Nodes that answered under this leader (reassignment targets).
    pub responsive: BTreeSet<NodeId>,
    /// Nodes declared dead (no Rounds sent; a State/Delta revives).
    pub dead: BTreeSet<NodeId>,
    pub round_started: Tick,
    pub collect: Option<Collect>,
    pub trace: ConvergenceTrace,
    pub gap: f64,
    pub converged: bool,
}

impl LeaderState {
    /// The initial coordinator: identity ownership, zero duals.
    pub fn bootstrap(leader: NodeId, k: usize, n_cols: usize, n_rows: usize) -> Self {
        let alpha = (0..k)
            .map(|s| {
                let (lo, hi) = shard_cols(n_cols, k, s);
                vec![0.0f32; hi - lo]
            })
            .collect();
        LeaderState {
            term: 0,
            round: 0,
            owners: (0..k).collect(),
            alpha,
            v: vec![0.0f32; n_rows],
            waiting: BTreeSet::new(),
            responsive: (0..k).collect(),
            dead: BTreeSet::new(),
            round_started: 0,
            collect: None,
            trace: ConvergenceTrace::new(format!("cluster-leader-{leader}")),
            gap: f64::INFINITY,
            converged: false,
        }
    }

    /// A freshly elected leader, waiting for States until `deadline`.
    pub fn collecting(leader: NodeId, term: u64, k: usize, deadline: Tick) -> Self {
        LeaderState {
            term,
            round: 0,
            owners: vec![leader; k],
            alpha: vec![Vec::new(); k],
            v: Vec::new(),
            waiting: BTreeSet::new(),
            responsive: BTreeSet::from([leader]),
            dead: BTreeSet::new(),
            round_started: 0,
            collect: Some(Collect {
                deadline,
                reported: BTreeSet::new(),
                owned: BTreeMap::new(),
                cached: BTreeMap::new(),
            }),
            trace: ConvergenceTrace::new(format!("cluster-leader-{leader}")),
            gap: f64::INFINITY,
            converged: false,
        }
    }

    /// Record one node's State during collect.  Conflicting claims for
    /// a shard (possible after split-brain) resolve to the highest
    /// claimant id, deterministically.
    pub fn offer(
        &mut self,
        src: NodeId,
        owned: Vec<(usize, Vec<f32>)>,
        cached: Vec<(usize, Vec<f32>)>,
    ) {
        let k = self.owners.len();
        if let Some(c) = &mut self.collect {
            c.reported.insert(src);
            for (s, a) in owned {
                let better = match c.owned.get(&s) {
                    Some((id, _)) => src > *id,
                    None => true,
                };
                if s < k && better {
                    c.owned.insert(s, (src, a));
                }
            }
            for (s, a) in cached {
                let better = match c.cached.get(&s) {
                    Some((id, _)) => src > *id,
                    None => true,
                };
                if s < k && better {
                    c.cached.insert(s, (src, a));
                }
            }
        }
        self.responsive.insert(src);
        self.dead.remove(&src);
    }

    /// Close the collect phase: resolve shard ownership and duals
    /// (owned claim > deposed-leader cache > zeros), rebuild the
    /// shared vector exactly, and leave the state ready for
    /// `start_round`.  Shards nobody reported are assigned round-robin
    /// over the responsive nodes.
    pub fn finish_collect(&mut self, data: &Dataset) {
        let k = self.owners.len();
        let n = data.n_cols();
        let Some(collect) = self.collect.take() else {
            return;
        };
        let live: Vec<NodeId> = self.responsive.iter().copied().collect();
        let mut spill = 0usize;
        for s in 0..k {
            let (lo, hi) = shard_cols(n, k, s);
            let want = hi - lo;
            let fit = |a: &Vec<f32>| a.len() == want;
            if let Some((id, a)) = collect.owned.get(&s).filter(|(_, a)| fit(a)) {
                self.owners[s] = *id;
                self.alpha[s] = a.clone();
            } else {
                self.owners[s] = live[spill % live.len()];
                spill += 1;
                self.alpha[s] = match collect.cached.get(&s).filter(|(_, a)| fit(a)) {
                    Some((_, a)) => a.clone(),
                    None => vec![0.0f32; want],
                };
            }
        }
        self.v = data.matvec_alpha(&self.flat_alpha());
    }

    /// The full dual vector: shards are contiguous column ranges in
    /// shard order, so concatenation is the global layout.
    pub fn flat_alpha(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.alpha.iter().map(Vec::len).sum());
        for a in &self.alpha {
            out.extend_from_slice(a);
        }
        out
    }

    /// Shard payloads owned by `node`, cloned from the cache.
    pub fn shards_of(&self, node: NodeId) -> Vec<(usize, Vec<f32>)> {
        self.owners
            .iter()
            .enumerate()
            .filter(|&(_, o)| *o == node)
            .map(|(s, _)| (s, self.alpha[s].clone()))
            .collect()
    }

    /// Number of distinct shard-owning nodes — the curvature scale
    /// `sigma` for the next round's local subproblems.
    pub fn sigma(&self) -> f32 {
        self.owners.iter().collect::<BTreeSet<_>>().len() as f32
    }

    /// Fold one node's Delta into the cache and the shared vector:
    /// per moved coordinate, one `axpy` of the dual difference.  Only
    /// shards the sender actually owns are accepted.
    pub fn apply_delta(&mut self, data: &Dataset, src: NodeId, shards: Vec<(usize, Vec<f32>)>) {
        let k = self.owners.len();
        let n = data.n_cols();
        let ops = data.as_ops();
        for (s, new_alpha) in shards {
            if s >= k || self.owners[s] != src {
                continue;
            }
            let (lo, hi) = shard_cols(n, k, s);
            if new_alpha.len() != hi - lo {
                continue;
            }
            for (off, &na) in new_alpha.iter().enumerate() {
                let ca = self.alpha[s][off];
                let diff = na - ca;
                if diff != 0.0 {
                    ops.axpy(lo + off, diff, &mut self.v);
                    self.alpha[s][off] = na;
                }
            }
        }
        self.responsive.insert(src);
        self.dead.remove(&src);
    }

    /// Evaluate the certificate: re-anchor `v = D alpha` exactly (fp32
    /// drift from incremental folding would otherwise floor the gap,
    /// same as every single-node engine), refresh the model, and push
    /// the exact duality gap on the trace.  Returns the gap.
    pub fn eval(&mut self, data: &Dataset, model: &mut dyn GlmModel, now: Tick) -> f64 {
        let alpha = self.flat_alpha();
        self.v = data.matvec_alpha(&alpha);
        model.epoch_refresh(&alpha);
        let y = data.targets();
        let obj = model.objective(&self.v, y, &alpha);
        let gap = glm::total_gap(model, data.as_block_ops(), &self.v, y, &alpha);
        // trace time column is virtual ticks: deterministic, seed-pure.
        self.trace.push(now as f64, self.round as usize, obj, gap);
        self.gap = gap;
        gap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetKind, Family};
    use crate::glm::Lasso;

    fn tiny() -> Dataset {
        Dataset::generated(DatasetKind::Tiny, Family::Regression, 1.0, 11)
    }

    #[test]
    fn bootstrap_partitions_all_columns() {
        let g = tiny();
        let ls = LeaderState::bootstrap(0, 4, g.n(), g.d());
        assert_eq!(ls.flat_alpha().len(), g.n());
        assert_eq!(ls.owners, vec![0, 1, 2, 3]);
        assert_eq!(ls.sigma(), 4.0);
    }

    #[test]
    fn apply_delta_keeps_v_consistent() {
        let g = tiny();
        let mut ls = LeaderState::bootstrap(0, 2, g.n(), g.d());
        // node 1 moves two coordinates of its shard
        let mut shard1 = ls.alpha[1].clone();
        shard1[0] = 0.5;
        shard1[1] = -0.25;
        ls.apply_delta(&g, 1, vec![(1, shard1)]);
        let exact = g.matvec_alpha(&ls.flat_alpha());
        for (a, b) in ls.v.iter().zip(&exact) {
            assert!((a - b).abs() < 1e-5, "incremental v diverged: {a} vs {b}");
        }
    }

    #[test]
    fn delta_from_non_owner_is_ignored() {
        let g = tiny();
        let mut ls = LeaderState::bootstrap(0, 2, g.n(), g.d());
        let forged = vec![(0usize, vec![1.0f32; ls.alpha[0].len()])];
        ls.apply_delta(&g, 1, forged); // node 1 does not own shard 0
        assert!(ls.alpha[0].iter().all(|&a| a == 0.0));
        assert!(ls.v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn collect_prefers_owned_claims_then_cache_then_zeros() {
        let g = tiny();
        let k = 3;
        let mut ls = LeaderState::collecting(2, 5, k, 100);
        let (lo0, hi0) = crate::cluster::shard_cols(g.n(), k, 0);
        let (lo1, hi1) = crate::cluster::shard_cols(g.n(), k, 1);
        let (lo2, hi2) = crate::cluster::shard_cols(g.n(), k, 2);
        // node 0 owns shard 0; node 1 died but the old leader cached
        // shard 1; nobody knows shard 2.
        ls.offer(0, vec![(0, vec![0.5; hi0 - lo0])], Vec::new());
        ls.offer(1, Vec::new(), vec![(1, vec![0.25; hi1 - lo1]), (0, vec![9.0; hi0 - lo0])]);
        ls.finish_collect(&g);
        assert_eq!(ls.owners[0], 0);
        assert!(ls.alpha[0].iter().all(|&a| a == 0.5), "owned claim wins over cache");
        assert!(ls.alpha[1].iter().all(|&a| a == 0.25), "cache fills dead shards");
        assert!(ls.alpha[2].iter().all(|&a| a == 0.0), "unknown shards reset");
        assert_eq!(ls.alpha[2].len(), hi2 - lo2);
        // v rebuilt exactly
        let exact = g.matvec_alpha(&ls.flat_alpha());
        assert_eq!(ls.v, exact);
    }

    #[test]
    fn eval_reports_the_exact_certificate() {
        let g = tiny();
        let mut model = Lasso::new(0.3);
        let mut ls = LeaderState::bootstrap(0, 2, g.n(), g.d());
        ls.round = 1;
        let gap = ls.eval(&g, &mut model, 10);
        // at alpha = 0 the gap equals the gap of the zero state,
        // recomputed independently:
        let zeros = vec![0.0f32; g.n()];
        let v0 = vec![0.0f32; g.d()];
        let mut fresh = Lasso::new(0.3);
        fresh.epoch_refresh(&zeros);
        let expect = glm::total_gap(&fresh, g.as_block_ops(), &v0, g.targets(), &zeros);
        assert!((gap - expect).abs() < 1e-9 * expect.abs().max(1.0));
        assert_eq!(ls.trace.points.len(), 1);
    }
}
