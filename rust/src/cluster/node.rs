//! The per-node state machine: worker, candidate, or leader.
//!
//! Every node runs the same code; the coordinator is just the node
//! currently in [`Role::Leader`].  Workers own shards as
//! [`crate::data::DatasetView`]s over contiguous column ranges and run
//! local sigma-scaled coordinate-descent passes that mirror
//! [`crate::glm::solve_reference`] exactly — at one shard-owning node
//! the cluster degenerates to the exact sequential oracle, which is
//! what the k=1 parity test in rust/tests/cluster_sim.rs pins down.
//!
//! Failure detection is timeout-based over virtual time: a follower
//! that stops hearing leader traffic for `election_timeout` (plus a
//! deterministic per-id stagger) starts a bully election; a leader
//! that waits longer than `worker_timeout` on a round declares the
//! silent owners dead and reassigns their shards.  See
//! [`super::coordinator`] for the protocol-level picture.

use std::collections::BTreeMap;

use super::coordinator::{LeaderState, Message};
use super::net::{Network, ReliableLink};
use super::run::{ClusterConfig, Timing};
use super::{shard_cols, NodeId, Tick};
use crate::data::{ColumnOps, Dataset};
use crate::glm::{self, GlmModel, ModelKind};

/// Bully-election role.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Follower,
    /// Sent `Election` to the higher ids; waiting for an `Alive`.
    Candidate { since: Tick },
    Leader,
}

/// One simulated node.
pub struct Node<'a> {
    pub id: NodeId,
    k: usize,
    data: &'a Dataset,
    model: Box<dyn GlmModel>,
    pub link: ReliableLink,
    timing: Timing,
    local_passes: usize,
    gap_tol: f64,
    max_rounds: u64,
    eval_every: u64,

    pub role: Role,
    pub term: u64,
    /// Highest-authority leader this node currently follows.
    pub leader: NodeId,
    last_heard: Tick,
    /// Highest `(term, round)` already processed (replay guard).
    last_round: (u64, u64),
    /// Leader-side state; `Some` iff `role == Leader`.
    pub lead: Option<LeaderState>,
    /// Owned shards: shard index -> local duals (worker side).
    shards: BTreeMap<usize, Vec<f32>>,
    /// A deposed leader's cache, offered at the next collect.
    cached: BTreeMap<usize, Vec<f32>>,

    pub finished: bool,
    pub converged: bool,
    pub final_gap: f64,
    pub elections: u64,
    pub failovers: u64,
}

impl<'a> Node<'a> {
    pub fn new(
        id: NodeId,
        data: &'a Dataset,
        model: Box<dyn GlmModel>,
        cfg: &ClusterConfig,
    ) -> Self {
        Node {
            id,
            k: cfg.nodes,
            data,
            model,
            link: ReliableLink::new(id, cfg.nodes, cfg.timing.rto),
            timing: cfg.timing,
            local_passes: cfg.local_passes.max(1),
            gap_tol: cfg.gap_tol,
            max_rounds: cfg.max_rounds.max(1),
            eval_every: cfg.eval_every.max(1),
            role: Role::Follower,
            term: 0,
            leader: cfg.initial_leader,
            last_heard: 0,
            last_round: (0, 0),
            lead: None,
            shards: BTreeMap::new(),
            cached: BTreeMap::new(),
            finished: false,
            converged: false,
            final_gap: f64::INFINITY,
            elections: 0,
            failovers: 0,
        }
    }

    /// Make this node the initial coordinator (before the first tick).
    pub fn bootstrap_leader(&mut self) {
        self.role = Role::Leader;
        self.leader = self.id;
        self.lead = Some(LeaderState::bootstrap(
            self.id,
            self.k,
            self.data.n_cols(),
            self.data.n_rows(),
        ));
    }

    pub fn is_leader(&self) -> bool {
        self.role == Role::Leader
    }

    pub fn is_finished_leader(&self) -> bool {
        self.is_leader() && self.finished
    }

    /// One scheduler step: consume messages, run timers, retransmit.
    pub fn step(&mut self, net: &mut Network) {
        for (src, msg) in self.link.poll(net) {
            self.handle(net, src, msg);
        }
        self.tick_timers(net);
        self.link.flush(net);
    }

    fn handle(&mut self, net: &mut Network, src: NodeId, msg: Message) {
        match msg {
            m @ Message::Round { .. } => self.on_round(net, src, m),
            Message::Delta { term, round, shards } => {
                self.leader_on_delta(net, src, term, round, shards)
            }
            Message::Stop { term, round, gap, converged } => {
                self.on_stop(net, src, term, round, gap, converged)
            }
            Message::Election { term } => self.on_election(net, src, term),
            Message::Alive { term } => self.on_alive(net, term),
            Message::Coordinator { term } => self.on_coordinator(net, src, term),
            Message::State { term, owned, cached } => {
                self.leader_on_state(net, src, term, owned, cached)
            }
        }
    }

    /// Leader-authority messages are ordered by `(term, sender id)`;
    /// anything not outranking the current belief is stale.
    fn accepts_leader(&self, term: u64, src: NodeId) -> bool {
        (term, src) >= (self.term, self.leader)
    }

    /// Adopt `src` as leader at `term` (pre-checked by
    /// `accepts_leader`).  A deposed leader stashes its cache so the
    /// duals it tracked for dead nodes survive into the next collect.
    fn adopt_leader(&mut self, net: &Network, term: u64, src: NodeId) {
        if self.is_leader() {
            if let Some(ls) = self.lead.take() {
                for (s, a) in ls.alpha.into_iter().enumerate() {
                    if !a.is_empty() {
                        self.cached.insert(s, a);
                    }
                }
            }
        }
        self.role = Role::Follower;
        self.term = term;
        self.leader = src;
        self.last_heard = net.now();
    }

    fn snapshot(map: &BTreeMap<usize, Vec<f32>>) -> Vec<(usize, Vec<f32>)> {
        map.iter().map(|(s, a)| (*s, a.clone())).collect()
    }

    // ------------------------------------------------------- worker --

    fn on_round(&mut self, net: &mut Network, src: NodeId, msg: Message) {
        let Message::Round { term, round, sigma, v, shards } = msg else {
            return;
        };
        if !self.accepts_leader(term, src) {
            if self.is_leader() {
                let t = self.term;
                self.link.send(net, src, Message::Coordinator { term: t });
            }
            return;
        }
        self.adopt_leader(net, term, src);
        if (term, round) <= self.last_round {
            return; // replayed or out-of-order older round
        }
        self.last_round = (term, round);
        // a higher-authority leader still training overrides an
        // earlier Stop (split-brain heal): participate again.
        self.finished = false;
        self.converged = false;
        self.shards = shards.into_iter().collect();
        let mut vloc = v;
        if !self.shards.is_empty() {
            self.local_pass(&mut vloc, sigma);
        }
        let reply = Self::snapshot(&self.shards);
        self.link.send(net, src, Message::Delta { term, round, shards: reply });
    }

    fn on_stop(
        &mut self,
        net: &mut Network,
        src: NodeId,
        term: u64,
        round: u64,
        gap: f64,
        converged: bool,
    ) {
        if !self.accepts_leader(term, src) {
            return;
        }
        self.adopt_leader(net, term, src);
        if (term, round) > self.last_round {
            self.last_round = (term, round);
        }
        self.finished = true;
        self.converged = converged;
        self.final_gap = gap;
    }

    // ----------------------------------------------------- election --

    fn on_election(&mut self, net: &mut Network, src: NodeId, term: u64) {
        if term > self.term {
            self.term = term;
            if let Some(ls) = &mut self.lead {
                ls.term = term;
            }
        }
        let t = self.term;
        self.link.send(net, src, Message::Alive { term: t });
        if self.is_leader() {
            if self.finished {
                let (round, gap, converged) = self.stop_payload();
                self.link.send(net, src, Message::Stop { term: t, round, gap, converged });
            } else {
                // reassert so the doubter resyncs instead of electing
                self.link.send(net, src, Message::Coordinator { term: t });
            }
        } else if self.role == Role::Follower && !self.finished {
            // we outrank the prober: contend ourselves
            self.start_election(net);
        }
    }

    fn on_alive(&mut self, net: &Network, term: u64) {
        if let Role::Candidate { .. } = self.role {
            self.role = Role::Follower;
            self.term = self.term.max(term);
            self.last_heard = net.now();
        }
    }

    fn on_coordinator(&mut self, net: &mut Network, src: NodeId, term: u64) {
        if !self.accepts_leader(term, src) {
            if self.is_leader() {
                let t = self.term;
                self.link.send(net, src, Message::Coordinator { term: t });
            }
            return;
        }
        self.adopt_leader(net, term, src);
        let owned = Self::snapshot(&self.shards);
        let cached = Self::snapshot(&self.cached);
        self.link.send(net, src, Message::State { term, owned, cached });
    }

    fn start_election(&mut self, net: &mut Network) {
        self.elections += 1;
        self.term += 1;
        self.leader = self.id;
        let term = self.term;
        if self.id + 1 >= self.k {
            // highest id: nobody can veto
            self.become_leader(net);
            return;
        }
        for higher in self.id + 1..self.k {
            self.link.send(net, higher, Message::Election { term });
        }
        self.role = Role::Candidate { since: net.now() };
    }

    fn become_leader(&mut self, net: &mut Network) {
        self.failovers += 1;
        self.role = Role::Leader;
        self.leader = self.id;
        let term = self.term;
        for node in 0..self.k {
            if node != self.id {
                self.link.send(net, node, Message::Coordinator { term });
            }
        }
        let deadline = net.now() + self.timing.state_timeout;
        let mut ls = LeaderState::collecting(self.id, term, self.k, deadline);
        ls.offer(self.id, Self::snapshot(&self.shards), Self::snapshot(&self.cached));
        self.lead = Some(ls);
        self.maybe_finish_collect(net); // k == 1 resolves immediately
        self.leader_advance(net);
    }

    // ------------------------------------------------------- leader --

    fn stop_payload(&self) -> (u64, f64, bool) {
        match &self.lead {
            Some(ls) => (ls.round, ls.gap, ls.converged),
            None => (self.last_round.1, self.final_gap, self.converged),
        }
    }

    fn leader_on_delta(
        &mut self,
        net: &mut Network,
        src: NodeId,
        term: u64,
        round: u64,
        shards: Vec<(usize, Vec<f32>)>,
    ) {
        if !self.is_leader() || term != self.term {
            return;
        }
        if self.finished {
            let (r, gap, converged) = self.stop_payload();
            self.link.send(net, src, Message::Stop { term, round: r, gap, converged });
            return;
        }
        let Some(mut ls) = self.lead.take() else {
            return;
        };
        if ls.collect.is_none() && round == ls.round {
            ls.apply_delta(self.data, src, shards);
            ls.waiting.remove(&src);
        } else {
            // stale round (or mid-collect): proof of life only
            ls.responsive.insert(src);
            ls.dead.remove(&src);
        }
        self.lead = Some(ls);
        self.leader_advance(net);
    }

    fn leader_on_state(
        &mut self,
        net: &mut Network,
        src: NodeId,
        term: u64,
        owned: Vec<(usize, Vec<f32>)>,
        cached: Vec<(usize, Vec<f32>)>,
    ) {
        if !self.is_leader() || term != self.term {
            return;
        }
        if self.finished {
            let (r, gap, converged) = self.stop_payload();
            self.link.send(net, src, Message::Stop { term, round: r, gap, converged });
            return;
        }
        if let Some(ls) = &mut self.lead {
            // outside a collect this only revives the reporter (its
            // shards were reassigned; it re-enters via empty Rounds)
            ls.offer(src, owned, cached);
        }
        self.maybe_finish_collect(net);
        self.leader_advance(net);
    }

    fn maybe_finish_collect(&mut self, net: &Network) {
        let now = net.now();
        let due = match &self.lead {
            Some(ls) => match &ls.collect {
                Some(c) => now >= c.deadline || c.reported.len() >= self.k,
                None => false,
            },
            None => false,
        };
        if due {
            if let Some(ls) = &mut self.lead {
                ls.finish_collect(self.data);
            }
        }
    }

    /// Worker-death detection: a round stalled past `worker_timeout`
    /// declares the silent owners dead and hands their shards (cached
    /// duals included — no progress lost) to responsive nodes.
    fn maybe_reassign(&mut self, net: &Network) {
        let now = net.now();
        let stalled = matches!(
            &self.lead,
            Some(ls) if ls.collect.is_none()
                && ls.round > 0
                && !ls.waiting.is_empty()
                && now.saturating_sub(ls.round_started) >= self.timing.worker_timeout
        );
        if !stalled {
            return;
        }
        let me = self.id;
        let Some(ls) = &mut self.lead else {
            return;
        };
        let newly_dead: Vec<NodeId> = ls.waiting.iter().copied().collect();
        for nd in &newly_dead {
            ls.dead.insert(*nd);
            ls.responsive.remove(nd);
        }
        ls.waiting.clear();
        ls.responsive.insert(me);
        let live: Vec<NodeId> = ls.responsive.iter().copied().collect();
        let mut spill = 0usize;
        for owner in ls.owners.iter_mut() {
            if ls.dead.contains(owner) {
                *owner = live[spill % live.len()];
                spill += 1;
            }
        }
        // cache + v stayed consistent (deltas fold on arrival), so the
        // abandoned round counts as complete; leader_advance moves on.
    }

    /// Drive the round state machine as far as it can go without
    /// waiting on the network.  Iterative on purpose: at k=1 the whole
    /// training run resolves in this loop (one round per iteration)
    /// and recursion would overflow on long runs.
    fn leader_advance(&mut self, net: &mut Network) {
        loop {
            if !self.is_leader() || self.finished {
                return;
            }
            let ready = matches!(
                &self.lead,
                Some(ls) if ls.collect.is_none() && ls.waiting.is_empty()
            );
            if !ready {
                return;
            }
            let Some(mut ls) = self.lead.take() else {
                return;
            };
            if ls.round > 0 {
                // the current round is complete: certify if due
                let due = ls.round % self.eval_every == 0 || ls.round >= self.max_rounds;
                if due {
                    let gap = ls.eval(self.data, &mut *self.model, net.now());
                    if gap <= self.gap_tol {
                        ls.converged = true;
                        self.lead = Some(ls);
                        self.finish_leader(net, true);
                        return;
                    }
                }
                if ls.round >= self.max_rounds {
                    self.lead = Some(ls);
                    self.finish_leader(net, false);
                    return;
                }
            }
            // start the next round
            ls.round += 1;
            ls.round_started = net.now();
            let sigma = ls.sigma();
            let term = self.term;
            let round = ls.round;
            for node in 0..self.k {
                if node == self.id || ls.dead.contains(&node) {
                    continue;
                }
                let payload = ls.shards_of(node);
                if !payload.is_empty() {
                    ls.waiting.insert(node);
                }
                self.link.send(
                    net,
                    node,
                    Message::Round { term, round, sigma, v: ls.v.clone(), shards: payload },
                );
            }
            // the leader's own shards run inline, same code as workers
            let mine = ls.shards_of(self.id);
            if !mine.is_empty() {
                self.shards = mine.into_iter().collect();
                let mut vloc = ls.v.clone();
                self.local_pass(&mut vloc, sigma);
                let updated = Self::snapshot(&self.shards);
                ls.apply_delta(self.data, self.id, updated);
            }
            self.lead = Some(ls);
            // loop: with no remote owners the round is already done
        }
    }

    fn finish_leader(&mut self, net: &mut Network, converged: bool) {
        self.finished = true;
        self.converged = converged;
        let (round, gap, _) = self.stop_payload();
        self.final_gap = gap;
        let term = self.term;
        for node in 0..self.k {
            if node != self.id {
                // dead nodes included: retransmission reaches a healed
                // partition eventually, so everyone can stop.
                self.link.send(net, node, Message::Stop { term, round, gap, converged });
            }
        }
    }

    // -------------------------------------------------------- timers --

    fn tick_timers(&mut self, net: &mut Network) {
        let now = net.now();
        match self.role {
            Role::Leader => {
                if !self.finished {
                    self.maybe_finish_collect(net);
                    self.maybe_reassign(net);
                    self.leader_advance(net);
                }
            }
            Role::Candidate { since } => {
                if now.saturating_sub(since) >= self.timing.alive_timeout {
                    self.become_leader(net);
                }
            }
            Role::Follower => {
                // deterministic per-id stagger so timeouts don't fire
                // in lockstep across the cluster
                let deadline = self.timing.election_timeout + 7 * self.id as Tick;
                if !self.finished && now.saturating_sub(self.last_heard) >= deadline {
                    self.start_election(net);
                }
            }
        }
    }

    // -------------------------------------------------- local solver --

    /// `local_passes` coordinate-descent sweeps over the owned shard
    /// views, starting from the broadcast shared vector.  Mirrors
    /// [`glm::solve_reference`] exactly — same per-epoch model refresh,
    /// same incremental-`w` discipline — except the curvature term is
    /// scaled by `sigma` (the shard-owner count), which is what makes
    /// the coordinator's "adding" aggregation safe.  At `sigma == 1`
    /// this *is* the sequential oracle.
    fn local_pass(&mut self, vloc: &mut [f32], sigma: f32) {
        let data = self.data;
        let y = data.targets();
        let n = data.n_cols();
        let k = self.k;
        let passes = self.local_passes;
        let model = &mut *self.model;
        let shards = &mut self.shards;
        let mut w = vec![0.0f32; data.n_rows()];
        for _ in 0..passes {
            let flat: Vec<f32> = shards.values().flat_map(|a| a.iter().copied()).collect();
            model.epoch_refresh(&flat);
            // dw/dv where the dual map is affine; None -> re-map on
            // change (same table as glm::solve_reference)
            let w_slope = match model.kind() {
                ModelKind::Lasso { .. }
                | ModelKind::Ridge { .. }
                | ModelKind::ElasticNet { .. } => Some(1.0f32),
                ModelKind::Svm { inv_scale, .. } | ModelKind::SvmL2 { inv_scale, .. } => {
                    Some(inv_scale)
                }
                ModelKind::Huber { .. } | ModelKind::Logistic { .. } => None,
            };
            glm::w_from_v(model, vloc, y, &mut w);
            let mut w_stale = false;
            for (&s, alpha) in shards.iter_mut() {
                let (lo, hi) = shard_cols(n, k, s);
                if alpha.len() != hi - lo {
                    continue; // malformed payload; leader re-sends next round
                }
                let view = data.col_range(lo, hi);
                for (jj, a) in alpha.iter_mut().enumerate() {
                    if w_stale {
                        glm::w_from_v(model, vloc, y, &mut w);
                        w_stale = false;
                    }
                    let u = view.dot(jj, &w);
                    let delta = model.delta(u, *a, view.sq_norm(jj) * sigma);
                    if delta != 0.0 {
                        *a += delta;
                        view.axpy(jj, delta, vloc);
                        match w_slope {
                            Some(slope) => view.axpy(jj, delta * slope, &mut w),
                            None => w_stale = true,
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::net::FaultPlan;
    use crate::data::{DatasetKind, Family};
    use crate::glm::Lasso;

    fn tiny() -> Dataset {
        Dataset::generated(DatasetKind::Tiny, Family::Regression, 1.0, 31)
    }

    fn cfg(k: usize) -> ClusterConfig {
        ClusterConfig { nodes: k, ..Default::default() }
    }

    #[test]
    fn bully_highest_id_wins_without_traffic() {
        let g = tiny();
        let c = cfg(3);
        let mut net = Network::new(3, FaultPlan::default(), 5);
        let mut nodes: Vec<Node> = (0..3)
            .map(|i| Node::new(i, &g, Box::new(Lasso::new(0.3)), &c))
            .collect();
        // no bootstrap leader at all: the cluster must elect one
        for _ in 0..(c.timing.election_timeout * 4) {
            net.step();
            for n in nodes.iter_mut() {
                n.step(&mut net);
            }
            if nodes.iter().any(|n| n.is_leader()) && nodes.iter().all(|n| n.leader == 2) {
                break;
            }
        }
        assert!(nodes[2].is_leader(), "highest id should win the bully election");
        assert!(nodes.iter().all(|n| n.leader == 2));
    }

    #[test]
    fn local_pass_at_sigma_one_matches_solve_reference() {
        let g = tiny();
        let c = cfg(1);
        let mut node = Node::new(0, &g, Box::new(Lasso::new(0.3)), &c);
        node.shards.insert(0, vec![0.0f32; g.n()]);
        let mut v_node = vec![0.0f32; g.d()];
        node.local_pass(&mut v_node, 1.0);

        let mut model = Lasso::new(0.3);
        let mut alpha = vec![0.0f32; g.n()];
        let mut v = vec![0.0f32; g.d()];
        glm::solve_reference(&mut model, g.as_ops(), g.targets(), &mut alpha, &mut v, 1);

        assert_eq!(node.shards[&0], alpha, "one local pass == one reference epoch");
        assert_eq!(v_node, v);
    }
}
