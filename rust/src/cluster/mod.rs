//! Multi-node sharded training with a failure-tolerant coordinator.
//!
//! HTHC parallelizes within one manycore socket; this layer scales the
//! same duality-gap-certified training *across* nodes following the
//! CoCoA-style local-subproblem scheme of Ioannou et al. ("Parallel
//! training of linear models without compromising convergence",
//! PAPERS.md): each node owns a contiguous column shard of `D` (a
//! [`crate::data::DatasetView`]), runs local coordinate descent against
//! a broadcast copy of the shared vector `v`, and ships back only its
//! dual variables for the shard.  The coordinator aggregates the
//! implied `v` deltas, re-anchors `v = D alpha` at eval rounds, and
//! certifies convergence with the exact duality gap over the full
//! dataset — the same certificate every single-node engine reports, so
//! cluster runs are directly comparable to `hthc train`.
//!
//! **Simulate-first.**  The whole cluster runs in-process on one real
//! thread, driven by a virtual-tick event scheduler ([`net::Network`])
//! with a seeded [`net::FaultPlan`] that can drop, delay, duplicate
//! messages, partition node sets and kill nodes at fixed ticks.  A run
//! is a pure function of `(dataset, model, ClusterConfig)`: every
//! failover, retransmission and election is reproducible from the
//! seed, which makes the failure machinery testable in CI the way a
//! real socket transport never is.  The mailbox handoff itself routes
//! through [`crate::sync`], so the mini-loom model checker explores
//! its interleavings too (rust/tests/model_check.rs).
//!
//! Layout:
//! - [`net`] — virtual-time transport: mailboxes, fault injection, and
//!   reliable-link semantics (retransmit + dedup) over the lossy wire.
//! - [`node`] — the per-node state machine: local solver passes over
//!   the owned shard views, plus the bully-election follower side.
//! - [`coordinator`] — the wire protocol and the leader's round/
//!   aggregation/certificate state.
//! - [`run`] — [`run::ClusterConfig`] / [`run::ClusterReport`] facade
//!   and the tick loop behind `hthc cluster --nodes K`.

pub mod coordinator;
pub mod net;
pub mod node;
pub mod run;

pub use coordinator::{LeaderState, Message};
pub use net::{DedupFilter, Envelope, FaultPlan, Mailbox, NetStats, Network, Packet, ReliableLink};
pub use node::{Node, Role};
pub use run::{run_cluster, ClusterConfig, ClusterReport, Timing};

/// Node identifier: nodes are `0..k`, and bully elections prefer the
/// highest live id.
pub type NodeId = usize;

/// Virtual time. One tick is one scheduler step; base message latency
/// is one tick, fault plans add more.
pub type Tick = u64;

/// Column range `[lo, hi)` of shard `s` out of `k` near-equal
/// contiguous shards over `n_cols` columns.  Matches
/// [`crate::data::DatasetView::shards`] so shard `s` of the full view
/// is exactly `data.col_range(lo, hi)`.
pub(crate) fn shard_cols(n_cols: usize, k: usize, s: usize) -> (usize, usize) {
    let base = n_cols / k;
    let rem = n_cols % k;
    let lo = s * base + s.min(rem);
    let hi = lo + base + usize::from(s < rem);
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::shard_cols;

    #[test]
    fn shard_cols_partition_the_columns() {
        for &(n, k) in &[(10usize, 3usize), (7, 7), (5, 1), (16, 4), (3, 2)] {
            let mut covered = 0;
            for s in 0..k {
                let (lo, hi) = shard_cols(n, k, s);
                assert_eq!(lo, covered, "shard {s} of ({n},{k}) not contiguous");
                assert!(hi >= lo);
                covered = hi;
            }
            assert_eq!(covered, n, "shards of ({n},{k}) do not cover");
        }
    }

    #[test]
    fn shard_cols_matches_dataset_view_shards() {
        use crate::data::{Dataset, DatasetKind, Family};
        let g = Dataset::generated(DatasetKind::Tiny, Family::Regression, 1.0, 7);
        let full = g.view();
        for k in [1usize, 2, 3, 4] {
            let views = full.shards(k);
            for (s, view) in views.iter().enumerate() {
                let (lo, hi) = shard_cols(g.n(), k, s);
                assert_eq!(view.parent_cols(), (lo..hi).collect::<Vec<_>>());
            }
        }
    }
}
