//! Virtual-time in-process transport with fault injection.
//!
//! The wire is *lossy by construction*: a [`FaultPlan`] decides, from a
//! seeded [`crate::util::Rng`], whether each unicast is dropped,
//! duplicated or delayed, and whether a partition currently severs the
//! pair.  On top of that raw wire, [`ReliableLink`] implements the
//! classic reliable-channel construction — per-source sequence
//! numbers, acks, retransmission with exponential backoff, and
//! receiver-side dedup ([`DedupFilter`]) — so the application layer
//! (the cluster protocol in [`super::coordinator`]) sees exactly-once
//! delivery as long as source and destination are eventually connected.
//!
//! Determinism: delivery order is a pure function of (send order, fault
//! seed).  In-flight messages live in a binary heap keyed by
//! `(due_tick, send_counter)`, so ties break by submission order, and
//! the only randomness is the fault plan's.  [`Mailbox`] is the one
//! concurrency-facing piece — the simulation itself is single-threaded,
//! but the mailbox handoff is the seam a real socket transport would
//! replace, so it locks through [`crate::sync`] and is exercised by the
//! model checker (rust/tests/model_check.rs).

use std::collections::{BTreeSet, BinaryHeap, VecDeque};

use super::coordinator::Message;
use super::{NodeId, Tick};
use crate::sync::Mutex;
use crate::util::Rng;

/// Retransmission backoff cap: a pending message to an unreachable
/// node is retried forever (that is what lets a healed partition
/// reconverge) but at most once per this many ticks, so dead peers do
/// not flood the scheduler.
const RTO_CAP: Tick = 128;

/// A unicast in flight or in a mailbox.
#[derive(Clone, Debug)]
pub struct Envelope {
    pub src: NodeId,
    pub dst: NodeId,
    pub packet: Packet,
}

/// Wire packets: payloads carry a per-source sequence number; acks
/// confirm one.  Acks ride the same lossy wire (an ack loss just costs
/// one redundant retransmission, which the receiver dedups).
#[derive(Clone, Debug)]
pub enum Packet {
    Data { seq: u64, msg: Message },
    Ack { seq: u64 },
}

/// Per-node inbound queue.  Locked through [`crate::sync`] so the
/// push/drain handoff is model-checkable; everything else in the
/// simulation is single-threaded.
#[derive(Debug)]
pub struct Mailbox {
    queue: Mutex<VecDeque<Envelope>>,
}

impl Default for Mailbox {
    fn default() -> Self {
        Self::new()
    }
}

impl Mailbox {
    pub fn new() -> Self {
        Mailbox { queue: Mutex::new(VecDeque::new()) }
    }

    pub fn push(&self, env: Envelope) {
        let mut q = self.queue.lock().unwrap_or_else(|e| e.into_inner());
        q.push_back(env);
    }

    /// Take everything queued, preserving arrival order.
    pub fn drain(&self) -> Vec<Envelope> {
        let mut q = self.queue.lock().unwrap_or_else(|e| e.into_inner());
        std::mem::take(&mut *q).into()
    }

    pub fn len(&self) -> usize {
        self.queue.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Receiver-side duplicate suppression: remembers every `(src, seq)`
/// already delivered to the application layer.
#[derive(Debug, Default)]
pub struct DedupFilter {
    seen: Vec<BTreeSet<u64>>,
}

impl DedupFilter {
    pub fn new(n_nodes: usize) -> Self {
        DedupFilter { seen: vec![BTreeSet::new(); n_nodes] }
    }

    /// True exactly once per `(src, seq)`; false for every replay.
    pub fn accept(&mut self, src: NodeId, seq: u64) -> bool {
        if src >= self.seen.len() {
            self.seen.resize_with(src + 1, BTreeSet::new);
        }
        self.seen[src].insert(seq)
    }
}

/// A scheduled interval `[from, to)` during which `island` is cut off
/// from the rest of the cluster (messages crossing the boundary are
/// dropped at delivery time, in either direction).
#[derive(Clone, Debug)]
pub struct Partition {
    pub from: Tick,
    pub to: Tick,
    pub island: Vec<NodeId>,
}

/// Deterministic failure script for one run.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Probability a unicast is silently dropped.
    pub drop_prob: f64,
    /// Probability a unicast is delivered twice.
    pub dup_prob: f64,
    /// Extra delivery delay, uniform in `0..=delay_max` ticks.
    pub delay_max: Tick,
    /// `(tick, node)` pairs: the node is dead from that tick on.
    pub kills: Vec<(Tick, NodeId)>,
    /// Scheduled partitions.
    pub partitions: Vec<Partition>,
}

impl FaultPlan {
    /// A lossy wire with no scripted kills or partitions.
    pub fn lossy(drop_prob: f64, dup_prob: f64, delay_max: Tick) -> Self {
        FaultPlan { drop_prob, dup_prob, delay_max, ..Default::default() }
    }

    /// Schedule `node` to die at `tick`.
    pub fn kill(mut self, tick: Tick, node: NodeId) -> Self {
        self.kills.push((tick, node));
        self
    }

    /// Schedule `island` to be cut off during `[from, to)`.
    pub fn partition(mut self, from: Tick, to: Tick, island: Vec<NodeId>) -> Self {
        self.partitions.push(Partition { from, to, island });
        self
    }

    /// Is the `(a, b)` pair severed by an active partition at `now`?
    fn severed(&self, now: Tick, a: NodeId, b: NodeId) -> bool {
        self.partitions.iter().any(|p| {
            now >= p.from
                && now < p.to
                && (p.island.contains(&a) != p.island.contains(&b))
        })
    }
}

/// Wire-level counters for the whole run.
#[derive(Clone, Copy, Debug, Default)]
pub struct NetStats {
    pub sent: u64,
    pub delivered: u64,
    pub dropped: u64,
    pub duplicated: u64,
    /// Aggregated from the per-node links by [`super::run`].
    pub retransmits: u64,
    /// Aggregated from the per-node links by [`super::run`].
    pub dedup_dropped: u64,
}

/// An envelope scheduled for delivery.  Ordered by `(due, order)`
/// *reversed*, so the std max-heap pops the earliest delivery first.
#[derive(Debug)]
struct Flight {
    due: Tick,
    order: u64,
    env: Envelope,
}

impl PartialEq for Flight {
    fn eq(&self, other: &Self) -> bool {
        (self.due, self.order) == (other.due, other.order)
    }
}
impl Eq for Flight {}
impl PartialOrd for Flight {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Flight {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (other.due, other.order).cmp(&(self.due, self.order))
    }
}

/// The virtual-time event scheduler: mailboxes + in-flight heap +
/// fault plan + liveness.
pub struct Network {
    now: Tick,
    rng: Rng,
    fault: FaultPlan,
    mailboxes: Vec<Mailbox>,
    in_flight: BinaryHeap<Flight>,
    next_order: u64,
    alive: Vec<bool>,
    pub stats: NetStats,
}

impl Network {
    pub fn new(n_nodes: usize, fault: FaultPlan, seed: u64) -> Self {
        Network {
            now: 0,
            rng: Rng::new(seed).fork(0xC1A5),
            fault,
            mailboxes: (0..n_nodes).map(|_| Mailbox::new()).collect(),
            in_flight: BinaryHeap::new(),
            next_order: 0,
            alive: vec![true; n_nodes],
            stats: NetStats::default(),
        }
    }

    pub fn now(&self) -> Tick {
        self.now
    }

    pub fn is_alive(&self, id: NodeId) -> bool {
        self.alive.get(id).copied().unwrap_or(false)
    }

    /// Submit a unicast.  Self-sends are delivered immediately and
    /// bypass the fault plan (a node never loses messages to itself);
    /// everything else takes >= 1 tick and is subject to drop /
    /// duplicate / delay decisions made here, plus the partition check
    /// at delivery time.
    pub fn send(&mut self, env: Envelope) {
        if env.src == env.dst {
            self.mailboxes[env.dst].push(env);
            return;
        }
        self.stats.sent += 1;
        if self.fault.drop_prob > 0.0 && self.rng.f64() < self.fault.drop_prob {
            self.stats.dropped += 1;
            return;
        }
        let copies = if self.fault.dup_prob > 0.0 && self.rng.f64() < self.fault.dup_prob {
            self.stats.duplicated += 1;
            2
        } else {
            1
        };
        for _ in 0..copies {
            let extra = if self.fault.delay_max > 0 {
                self.rng.below(self.fault.delay_max as usize + 1) as Tick
            } else {
                0
            };
            let due = self.now + 1 + extra;
            let order = self.next_order;
            self.next_order += 1;
            self.in_flight.push(Flight { due, order, env: env.clone() });
        }
    }

    /// Advance one tick: apply scripted kills, then move every due
    /// in-flight envelope into its destination mailbox (or drop it if
    /// the destination is dead or the pair is currently partitioned).
    pub fn step(&mut self) {
        self.now += 1;
        for &(tick, node) in &self.fault.kills {
            if tick == self.now && node < self.alive.len() {
                self.alive[node] = false;
            }
        }
        while let Some(top) = self.in_flight.peek() {
            if top.due > self.now {
                break;
            }
            // PANIC-OK: peek() just proved the heap is non-empty.
            let flight = self.in_flight.pop().expect("heap non-empty after peek");
            let env = flight.env;
            if !self.is_alive(env.dst) || self.fault.severed(self.now, env.src, env.dst) {
                self.stats.dropped += 1;
                continue;
            }
            self.stats.delivered += 1;
            self.mailboxes[env.dst].push(env);
        }
    }

    /// Drain node `id`'s mailbox.
    pub fn drain(&mut self, id: NodeId) -> Vec<Envelope> {
        self.mailboxes[id].drain()
    }
}

/// One unacked payload awaiting retransmission.
#[derive(Clone, Debug)]
struct Pending {
    seq: u64,
    dst: NodeId,
    msg: Message,
    next_at: Tick,
    interval: Tick,
}

/// Per-node reliable-channel endpoint: sequences outbound payloads,
/// retransmits until acked (with exponential backoff capped at
/// [`RTO_CAP`]), acks and dedups inbound ones.
pub struct ReliableLink {
    id: NodeId,
    rto: Tick,
    next_seq: u64,
    pending: Vec<Pending>,
    dedup: DedupFilter,
    pub retransmits: u64,
    pub dedup_dropped: u64,
}

impl ReliableLink {
    pub fn new(id: NodeId, n_nodes: usize, rto: Tick) -> Self {
        ReliableLink {
            id,
            rto: rto.max(1),
            next_seq: 0,
            pending: Vec::new(),
            dedup: DedupFilter::new(n_nodes),
            retransmits: 0,
            dedup_dropped: 0,
        }
    }

    /// Send `msg` reliably: it will be retransmitted until acked.
    pub fn send(&mut self, net: &mut Network, dst: NodeId, msg: Message) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending.push(Pending {
            seq,
            dst,
            msg: msg.clone(),
            next_at: net.now() + self.rto,
            interval: self.rto,
        });
        net.send(Envelope { src: self.id, dst, packet: Packet::Data { seq, msg } });
    }

    /// Drain the mailbox: consume acks, ack + dedup payloads, and
    /// return the application messages in arrival order (each exactly
    /// once).
    pub fn poll(&mut self, net: &mut Network) -> Vec<(NodeId, Message)> {
        let mut out = Vec::new();
        for env in net.drain(self.id) {
            match env.packet {
                Packet::Ack { seq } => {
                    self.pending.retain(|p| !(p.dst == env.src && p.seq == seq));
                }
                Packet::Data { seq, msg } => {
                    net.send(Envelope {
                        src: self.id,
                        dst: env.src,
                        packet: Packet::Ack { seq },
                    });
                    if self.dedup.accept(env.src, seq) {
                        out.push((env.src, msg));
                    } else {
                        self.dedup_dropped += 1;
                    }
                }
            }
        }
        out
    }

    /// Retransmit every overdue pending payload.
    pub fn flush(&mut self, net: &mut Network) {
        let now = net.now();
        let mut resend = Vec::new();
        for p in &mut self.pending {
            if now >= p.next_at {
                p.interval = (p.interval * 2).min(RTO_CAP);
                p.next_at = now + p.interval;
                resend.push((p.dst, p.seq, p.msg.clone()));
            }
        }
        for (dst, seq, msg) in resend {
            self.retransmits += 1;
            net.send(Envelope { src: self.id, dst, packet: Packet::Data { seq, msg } });
        }
    }

    /// Unacked payloads still awaiting an ack (test observability).
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(term: u64) -> Message {
        Message::Alive { term }
    }

    fn term_of(m: &Message) -> u64 {
        match m {
            Message::Alive { term } => *term,
            _ => u64::MAX,
        }
    }

    #[test]
    fn clean_wire_delivers_in_order() {
        let mut net = Network::new(2, FaultPlan::default(), 1);
        let mut a = ReliableLink::new(0, 2, 4);
        let mut b = ReliableLink::new(1, 2, 4);
        for t in 0..5 {
            a.send(&mut net, 1, msg(t));
        }
        let mut got = Vec::new();
        for _ in 0..10 {
            net.step();
            got.extend(b.poll(&mut net).into_iter().map(|(_, m)| term_of(&m)));
            a.flush(&mut net);
            let _ = a.poll(&mut net); // consume acks
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        assert_eq!(a.pending_len(), 0, "all payloads acked");
    }

    #[test]
    fn lossy_wire_still_delivers_exactly_once() {
        // Heavy loss + duplication + jitter: the reliable link must get
        // every message through exactly once, in some order.
        let mut net = Network::new(2, FaultPlan::lossy(0.4, 0.3, 3), 99);
        let mut a = ReliableLink::new(0, 2, 4);
        let mut b = ReliableLink::new(1, 2, 4);
        let n_msgs = 20u64;
        for t in 0..n_msgs {
            a.send(&mut net, 1, msg(t));
        }
        let mut got = Vec::new();
        for _ in 0..2000 {
            net.step();
            got.extend(b.poll(&mut net).into_iter().map(|(_, m)| term_of(&m)));
            a.flush(&mut net);
            let _ = a.poll(&mut net);
            if got.len() == n_msgs as usize && a.pending_len() == 0 {
                break;
            }
        }
        let mut sorted = got.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted, (0..n_msgs).collect::<Vec<_>>(), "got {got:?}");
        assert!(net.stats.dropped > 0, "fault plan never bit");
        assert!(a.retransmits > 0, "loss must force retransmission");
    }

    #[test]
    fn partition_cuts_and_heals() {
        let plan = FaultPlan::default().partition(2, 50, vec![1]);
        let mut net = Network::new(2, plan, 7);
        let mut a = ReliableLink::new(0, 2, 4);
        let mut b = ReliableLink::new(1, 2, 4);
        net.step(); // now = 1: send before the partition opens at 2
        a.send(&mut net, 1, msg(42));
        let mut seen_at = None;
        for _ in 0..300 {
            net.step();
            if let Some((_, m)) = b.poll(&mut net).into_iter().next() {
                seen_at = Some((net.now(), term_of(&m)));
                break;
            }
            a.flush(&mut net);
            let _ = a.poll(&mut net);
        }
        let (tick, t) = seen_at.unwrap_or((0, 0));
        assert_eq!(t, 42);
        assert!(tick >= 50, "delivery at {tick} should wait for the heal");
    }

    #[test]
    fn kills_silence_a_node() {
        let plan = FaultPlan::default().kill(3, 1);
        let mut net = Network::new(2, plan, 7);
        let mut a = ReliableLink::new(0, 2, 4);
        for _ in 0..10 {
            net.step();
        }
        assert!(!net.is_alive(1));
        a.send(&mut net, 1, msg(1));
        for _ in 0..20 {
            net.step();
            a.flush(&mut net);
        }
        assert!(a.pending_len() > 0, "no ack can ever come back");
        assert!(net.stats.dropped > 0);
    }
}
