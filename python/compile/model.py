"""L2: the jax compute graphs HTHC offloads via PJRT.

Three families, each jitted with fixed shapes and lowered by ``aot.py``:

* ``gaps_fn``      — task A's bulk work: z = gap_transform(D^T w, alpha)
                     with the D^T w through the L1 Pallas kernel and the
                     per-model transform fused on top (runtime scalars
                     lam / n / lipschitz-B, so one artifact serves all
                     hyperparameters).
* ``gaps_q4_fn``   — same over the 4-bit packed representation.
* ``cd_epoch_fn``  — an exact sequential CD epoch over a selected batch
                     (lax.scan).  This is the T_B = 1 oracle for task B
                     and the numerics cross-check the rust integration
                     tests run against the native implementation.

All functions return tuples (lowered with return_tuple semantics — the
rust loader unwraps with ``to_tuple1``/``to_tuple``).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import gap as gap_kernel
from .kernels import quantized as q4_kernel
from .kernels import ref
from .kernels import sparse_ell


def make_gaps_fn(model, *, d_tile=None, n_tile=None):
    """Fused gap computation; shapes fixed at lowering time.

    Signature: (D (d,n) f32, w (d,) f32, alpha (n,) f32,
                lam f32, nn f32, lip_b f32) -> (z (n,) f32,)
    """

    def fn(d_mat, w, alpha, lam, nn, lip_b):
        kw = {}
        if d_tile is not None:
            kw["d_tile"] = d_tile
        if n_tile is not None:
            kw["n_tile"] = n_tile
        u = gap_kernel.dtw(d_mat, w, **kw)
        z = ref.gap_transform(model, u, alpha, lam, nn, lip_b)
        # Keep-alive: jax.jit prunes unused inputs from the lowered
        # signature (e.g. nn for lasso), which would break the uniform
        # rust calling convention.  0*x is folded by XLA but the
        # parameter survives in the entry layout.
        return (z + 0.0 * (lam + nn + lip_b),)

    return fn


def make_gaps_q4_fn(model, *, d_tile=None, n_tile=None):
    """Quantized variant of ``make_gaps_fn``.

    Signature: (packed (d/2,n) u8, scales (d/QGROUP,n) f32, w (d,) f32,
                alpha (n,) f32, lam f32, nn f32, lip_b f32) -> (z,)
    """

    def fn(packed, scales, w, alpha, lam, nn, lip_b):
        kw = {}
        if d_tile is not None:
            kw["d_tile"] = d_tile
        if n_tile is not None:
            kw["n_tile"] = n_tile
        u = q4_kernel.dtw_q4(packed, scales, w, **kw)
        z = ref.gap_transform(model, u, alpha, lam, nn, lip_b)
        return (z + 0.0 * (lam + nn + lip_b),)  # keep-alive, see make_gaps_fn

    return fn


def make_gaps_ell_fn(model, *, k_tile=None, n_tile=None):
    """Sparse (ELL-padded) gap computation — the TPU adaptation of the
    paper's §IV-D sparse path (see kernels/sparse_ell.py).

    Signature: (idx (k_max,n) i32, val (k_max,n) f32, w (d,) f32,
                alpha (n,) f32, lam f32, nn f32, lip_b f32) -> (z,)
    """

    def fn(idx, val, w, alpha, lam, nn, lip_b):
        kw = {}
        if k_tile is not None:
            kw["k_tile"] = k_tile
        if n_tile is not None:
            kw["n_tile"] = n_tile
        u = sparse_ell.ell_dtw(idx, val, w, **kw)
        z = ref.gap_transform(model, u, alpha, lam, nn, lip_b)
        return (z + 0.0 * (lam + nn + lip_b),)  # keep-alive, see make_gaps_fn

    return fn


def make_cd_epoch_fn(model):
    """Sequential CD epoch over a batch (task B oracle, T_B = 1).

    Signature: (D_batch (d,m) f32, v (d,) f32, alpha (m,) f32, y (d,) f32,
                lam f32, nn f32) -> (v' (d,), alpha' (m,))
    """

    def fn(d_batch, v, alpha, y, lam, nn):
        v2, a2, _ = ref.cd_epoch(model, d_batch, v, alpha, y, lam, nn)
        keep = 0.0 * (lam + nn + jnp.sum(y) * 0.0)  # see make_gaps_fn
        return (v2 + keep, a2)

    return fn


def make_apply_deltas_fn(*, d_tile=None):
    """Batched shared-vector update v' = v + D_batch @ deltas (Pallas).

    Signature: (D_batch (d,m) f32, deltas (m,) f32, v (d,) f32) -> (v',)
    """

    def fn(d_batch, deltas, v):
        kw = {"d_tile": d_tile} if d_tile is not None else {}
        return (gap_kernel.apply_deltas(d_batch, deltas, v, **kw),)

    return fn


# ---------------------------------------------------------------------------
# Artifact catalogue: everything `aot.py` lowers, with shapes.
# Names are stable — the rust runtime resolves artifacts by these names
# via artifacts/manifest.txt.
# ---------------------------------------------------------------------------

S = jax.ShapeDtypeStruct
F32 = jnp.float32
U8 = jnp.uint8
SCALAR = S((), F32)


def catalogue():
    """Returns list of (name, fn, example_args) to lower."""
    out = []
    for model in ref.MODELS:
        for (d, n) in ((1024, 256), (4096, 512)):
            out.append(
                (
                    f"gaps_{model}_{d}x{n}",
                    make_gaps_fn(model),
                    (
                        S((d, n), F32),
                        S((d,), F32),
                        S((n,), F32),
                        SCALAR,
                        SCALAR,
                        SCALAR,
                    ),
                )
            )
        d, n = 1024, 256
        out.append(
            (
                f"gaps_q4_{model}_{d}x{n}",
                make_gaps_q4_fn(model),
                (
                    S((d // 2, n), U8),
                    S((d // ref.QGROUP, n), F32),
                    S((d,), F32),
                    S((n,), F32),
                    SCALAR,
                    SCALAR,
                    SCALAR,
                ),
            )
        )
        kmax, ncols, dvec = 128, 256, 2048
        out.append(
            (
                f"gaps_ell_{model}_{kmax}x{ncols}",
                make_gaps_ell_fn(model),
                (
                    S((kmax, ncols), jnp.int32),
                    S((kmax, ncols), F32),
                    S((dvec,), F32),
                    S((ncols,), F32),
                    SCALAR,
                    SCALAR,
                    SCALAR,
                ),
            )
        )
        d, m = 1024, 64
        out.append(
            (
                f"cd_epoch_{model}_{d}x{m}",
                make_cd_epoch_fn(model),
                (
                    S((d, m), F32),
                    S((d,), F32),
                    S((m,), F32),
                    S((d,), F32),
                    SCALAR,
                    SCALAR,
                ),
            )
        )
    d, m = 1024, 64
    out.append(
        (
            f"apply_deltas_{d}x{m}",
            make_apply_deltas_fn(),
            (S((d, m), F32), S((m,), F32), S((d,), F32)),
        )
    )
    return out
