"""AOT lowering: jax -> HLO *text* artifacts for the rust PJRT runtime.

HLO text (NOT ``lowered.compile().serialize()`` / serialized protos) is
the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (what the published ``xla``
crate binds) rejects (``proto.id() <= INT_MAX``).  The text parser
reassigns ids, so text round-trips cleanly.  See
/opt/xla-example/gen_hlo.py and its README.

Also writes ``artifacts/manifest.txt``: one line per artifact,
``name <tab> relative-path <tab> arg-signature`` where arg-signature is a
comma-separated list of ``dtype:dim0xdim1`` entries — parsed by
``rust/src/runtime/manifest.rs``.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sig_of(args) -> str:
    parts = []
    for a in args:
        dims = "x".join(str(d) for d in a.shape) if a.shape else "scalar"
        parts.append(f"{a.dtype.name}:{dims}")
    return ",".join(parts)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for name, fn, example_args in model.catalogue():
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        rel = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, rel)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name}\t{rel}\t{sig_of(example_args)}")
        print(f"  wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
