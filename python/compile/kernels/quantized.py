"""L1 Pallas kernel: 4-bit quantized tiled inner products (paper Sec. IV-E).

HTHC's quantized path (Clover-style): the data matrix D is stored as
4-bit codes with per-group f32 scales; v / alpha stay f32.  The win is
data movement (4x fewer bytes of D over the memory bus) at the cost of
unpack arithmetic — exactly the trade this kernel expresses: the packed
tile is unpacked and dequantized *in VMEM* after the (4x smaller)
HBM->VMEM transfer, then hits the same FMA loop as the f32 kernel.

Layout: codes are packed two-per-byte along the d axis (low nibble =
even row, high nibble = odd row, bias +8), scales are (d/QGROUP, n).
Matches ``ref.pack4`` / ``ref.gaps_quantized``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import QGROUP

D_TILE = 512  # rows of unpacked D per tile; must be % (2*QGROUP) == 0
N_TILE = 256


def _q4_matvec_kernel(p_ref, s_ref, w_ref, o_ref):
    """One (d_tile, n_tile) tile: unpack nibbles, dequantize, partial dot.

    Grid = (n_tiles, d_tiles), reduction axis fastest; o_ref revisited.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    packed = p_ref[...]  # (d_tile/2, n_tile) uint8
    lo = (packed & 0xF).astype(jnp.float32) - 8.0
    hi = (packed >> 4).astype(jnp.float32) - 8.0
    d2, ncols = packed.shape
    # Interleave even/odd rows: (d/2, 2, n) -> (d, n).
    codes = jnp.stack([lo, hi], axis=1).reshape(d2 * 2, ncols)
    scale = jnp.repeat(s_ref[...], QGROUP, axis=0)  # (d_tile, n_tile)
    deq = codes * scale
    o_ref[...] += jnp.dot(
        deq.T, w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("d_tile", "n_tile"))
def dtw_q4(packed, scales, w, *, d_tile=D_TILE, n_tile=N_TILE):
    """u = dequant(D)^T w over a 4-bit packed matrix.

    packed: (d/2, n) uint8; scales: (d/QGROUP, n) f32; w: (d,) f32.
    """
    d2, n = packed.shape
    d = d2 * 2
    assert d % d_tile == 0 and n % n_tile == 0, (d, n)
    assert d_tile % (2 * QGROUP) == 0
    grid = (n // n_tile, d // d_tile)
    return pl.pallas_call(
        _q4_matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d_tile // 2, n_tile), lambda i, k: (k, i)),
            pl.BlockSpec((d_tile // QGROUP, n_tile), lambda i, k: (k, i)),
            pl.BlockSpec((d_tile,), lambda i, k: (k,)),
        ],
        out_specs=pl.BlockSpec((n_tile,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(packed, scales, w)
