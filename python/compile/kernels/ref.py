"""Pure-jnp correctness oracles for the HTHC kernels.

Everything here is the *definition* of correct; the Pallas kernels in
``gap.py`` / ``quantized.py`` and the jax scan in ``cd_epoch.py`` are
tested against these functions (pytest + hypothesis in ``python/tests``).

Problem setup (paper Eq. (1)):  min_alpha  f(D alpha) + sum_i g_i(alpha_i)
with w := grad f(D alpha).  The coordinate-wise duality gap (paper Eq. (2)):

    gap_i(alpha_i; w) = alpha_i <w, d_i> + g_i(alpha_i) + g_i*(-<w, d_i>)

Models
------
lasso:    f(v) = 1/2 ||v - y||^2,  g_i(a) = lam |a|.
          g_i* is unbounded, so we use the Lipschitzing trick of
          Duenner et al. [23]: restrict |a| <= B, giving
          g_i*(u) = B max(0, |u| - lam).
svm:      dual hinge SVM.  f(v) = 1/(2 lam n^2) ||v||^2 over v = X alpha
          (columns pre-scaled by labels), g_i(a) = -a/n + I_[0,1](a),
          g_i*(u) = max(0, u + 1/n).
ridge:    f(v) = 1/2 ||v - y||^2,  g_i(a) = lam/2 a^2,
          g_i*(u) = u^2 / (2 lam).  Gap is exact (no trick needed).
"""

import jax
import jax.numpy as jnp

MODELS = ("lasso", "svm", "ridge")


def primal_dual_w(model, v, y, lam, n):
    """w = grad f(v) for each model (paper Sec. II-C)."""
    if model == "lasso" or model == "ridge":
        return v - y
    if model == "svm":
        return v / (lam * n * n)
    raise ValueError(model)


def gap_transform(model, u, alpha, lam, n, lip_b):
    """Coordinate-wise duality gap from u_i = <w, d_i> and alpha_i.

    This is the scalar function ``h`` of paper Eq. (3), vectorized.
    """
    if model == "lasso":
        return alpha * u + lam * jnp.abs(alpha) + lip_b * jnp.maximum(
            0.0, jnp.abs(u) - lam
        )
    if model == "svm":
        return alpha * u - alpha / n + jnp.maximum(0.0, 1.0 / n - u)
    if model == "ridge":
        # (u + lam a)^2 / (2 lam), exact gap for L2 regularization.
        t = u + lam * alpha
        return t * t / (2.0 * lam)
    raise ValueError(model)


def gaps(model, d_mat, w, alpha, lam, n, lip_b):
    """Reference for the fused gap kernel: z = h(D^T w, alpha).

    d_mat: (d, n) column-major data tile; w: (d,); alpha: (n,).
    """
    u = d_mat.T @ w
    return gap_transform(model, u, alpha, lam, n, lip_b)


def cd_delta(model, u, alpha, sq_norm, lam, n):
    """Closed-form coordinate update delta (paper Eq. (4)'s h-hat).

    u = <w, d_i> with w the *current* dual-mapped vector; sq_norm = ||d_i||^2.
    Returns delta with alpha_i+ = alpha_i + delta.
    """
    safe = jnp.maximum(sq_norm, 1e-12)
    if model == "lasso":
        # alpha+ = soft_threshold(alpha - u/||d||^2, lam/||d||^2)
        raw = alpha - u / safe
        thr = lam / safe
        new = jnp.sign(raw) * jnp.maximum(jnp.abs(raw) - thr, 0.0)
        return jnp.where(sq_norm > 0.0, new - alpha, 0.0)
    if model == "svm":
        # Newton step on the dual coordinate, clipped to [0, 1].
        hess = safe / (lam * n * n)
        new = jnp.clip(alpha - (u - 1.0 / n) / hess, 0.0, 1.0)
        return jnp.where(sq_norm > 0.0, new - alpha, 0.0)
    if model == "ridge":
        # minimize along the coordinate:
        #   d/d(delta) [ 1/2||v + delta d - y||^2 + lam/2 (a+delta)^2 ] = 0
        #   => delta (||d||^2 + lam) = -(u + lam a)
        delta = -(u + lam * alpha) / (safe + lam)
        return jnp.where(sq_norm > 0.0, delta, 0.0)
    raise ValueError(model)


def cd_epoch(model, d_batch, v, alpha_batch, y, lam, n):
    """Sequential (exact) coordinate descent over one batch.

    d_batch: (d, m) selected columns; alpha_batch: (m,); v: (d,) = D alpha.
    Returns (v', alpha_batch', deltas).  This is the oracle for task B with
    T_B = 1 (async SCD with one updater is exactly sequential SCD).
    """

    def step(carry, i):
        v_c, a_c = carry
        col = d_batch[:, i]
        w = primal_dual_w(model, v_c, y, lam, n)
        u = col @ w
        sq = col @ col
        delta = cd_delta(model, u, a_c[i], sq, lam, n)
        return (v_c + delta * col, a_c.at[i].add(delta)), delta

    (v2, a2), deltas = jax.lax.scan(
        step, (v, alpha_batch), jnp.arange(d_batch.shape[1])
    )
    return v2, a2, deltas


# ---------------------------------------------------------------------------
# 4-bit quantization reference (paper Sec. IV-E, Clover-style)
# ---------------------------------------------------------------------------

QGROUP = 64  # elements per scale group


def quantize4(x):
    """Deterministic (round-to-nearest) 4-bit quantization with per-group
    scales. x: (d,) with d % QGROUP == 0.  Returns (codes int8 in [-7, 7],
    scales (d/QGROUP,)).  Dequantized value = code * scale.
    """
    g = x.reshape(-1, QGROUP)
    absmax = jnp.max(jnp.abs(g), axis=1)
    scale = jnp.where(absmax > 0, absmax / 7.0, 1.0)
    codes = jnp.clip(jnp.round(g / scale[:, None]), -8, 7).astype(jnp.int8)
    return codes.reshape(-1), scale


def dequantize4(codes, scales):
    g = codes.reshape(-1, QGROUP).astype(jnp.float32)
    return (g * scales[:, None]).reshape(-1)


def pack4(codes):
    """Pack int8 codes in [-8,7] into uint8 nibbles (two per byte).

    Low nibble = even index, high nibble = odd index. Biased by +8.
    """
    b = (codes.astype(jnp.int32) + 8).astype(jnp.uint8)
    lo = b[0::2]
    hi = b[1::2]
    return lo | (hi << 4)


def unpack4(packed):
    lo = (packed & 0xF).astype(jnp.int32) - 8
    hi = (packed >> 4).astype(jnp.int32) - 8
    out = jnp.stack([lo, hi], axis=1).reshape(-1)
    return out.astype(jnp.int8)


def gaps_quantized(model, packed, scales, w, alpha, lam, n, lip_b):
    """Reference fused gap kernel over a 4-bit packed data tile.

    packed: (d//2, n) uint8; scales: (d//QGROUP, n) f32; w: (d,).
    """
    d2, ncols = packed.shape
    d = d2 * 2
    lo = (packed & 0xF).astype(jnp.float32) - 8.0
    hi = (packed >> 4).astype(jnp.float32) - 8.0
    codes = jnp.zeros((d, ncols), jnp.float32)
    codes = codes.at[0::2, :].set(lo).at[1::2, :].set(hi)
    scale_full = jnp.repeat(scales, QGROUP, axis=0)
    deq = codes * scale_full
    u = deq.T @ w
    return gap_transform(model, u, alpha, lam, n, lip_b)
