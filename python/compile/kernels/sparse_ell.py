"""L1 Pallas kernel: gap inner products over ELL-padded sparse columns.

HTHC's sparse path on KNL uses chunked CSC with AVX-512 gathers
(paper §IV-D).  The TPU adaptation cannot gather efficiently from HBM,
so the working set is re-laid-out as **ELLPACK**: every column padded to
a fixed nnz budget `k_max`, giving dense (k_max, n) index/value tiles —
regular enough for VPU gathers from a VMEM-resident `w`.  Padding
entries point at row 0 with value 0, contributing nothing.

This trades FLOPs-on-padding for regularity, the classic ELL trade; the
chunk-length distribution analysis in `data::sparse` (rust side) picks
`k_max` per working set exactly like the paper's chunk pool sizes its
stack from the m densest columns.

interpret=True as everywhere (CPU PJRT cannot run Mosaic calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

K_TILE = 64   # padded-nnz rows per tile
N_TILE = 256  # columns per tile


def _ell_matvec_kernel(idx_ref, val_ref, w_ref, o_ref):
    """Grid = (n_tiles, k_tiles); reduction over the padded-nnz axis.

    w is small enough to sit whole in VMEM (the dual-mapped vector for
    the sparse sets is the dense v, bounded by the sample count), so the
    BlockSpec maps the full w to every tile and the gather is VMEM-local.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx = idx_ref[...]  # (k_tile, n_tile) int32 row ids
    val = val_ref[...]  # (k_tile, n_tile) f32
    w = w_ref[...]      # (d,) f32, full vector
    gathered = w[idx]   # (k_tile, n_tile) VMEM gather
    o_ref[...] += jnp.sum(gathered * val, axis=0)


@functools.partial(jax.jit, static_argnames=("k_tile", "n_tile"))
def ell_dtw(idx, val, w, *, k_tile=K_TILE, n_tile=N_TILE):
    """u = D^T w where D is given in ELL form.

    idx: (k_max, n) int32 (padding rows point at 0);
    val: (k_max, n) f32 (padding value 0.0);
    w:   (d,) f32.
    """
    k_max, n = idx.shape
    assert k_max % k_tile == 0 and n % n_tile == 0, (k_max, n)
    grid = (n // n_tile, k_max // k_tile)
    return pl.pallas_call(
        _ell_matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k_tile, n_tile), lambda i, k: (k, i)),
            pl.BlockSpec((k_tile, n_tile), lambda i, k: (k, i)),
            pl.BlockSpec(w.shape, lambda i, k: tuple(0 for _ in w.shape)),
        ],
        out_specs=pl.BlockSpec((n_tile,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(idx, val, w)


def to_ell(cols, d, k_max):
    """Pack a list of [(row, value), ...] columns into ELL arrays.

    Columns longer than k_max are truncated (callers size k_max from the
    densest column, as the rust chunk pool does).  Returns (idx, val).
    """
    import numpy as np

    n = len(cols)
    idx = np.zeros((k_max, n), np.int32)
    val = np.zeros((k_max, n), np.float32)
    for j, col in enumerate(cols):
        for k, (r, x) in enumerate(col[:k_max]):
            assert 0 <= r < d
            idx[k, j] = r
            val[k, j] = x
    return jnp.asarray(idx), jnp.asarray(val)
