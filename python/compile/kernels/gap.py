"""L1 Pallas kernel: tiled column-wise inner products u = D^T w.

This is HTHC's compute hot spot (paper Eq. (3)/(4): every gap evaluation
and every coordinate update is dominated by <w, d_i>).  The paper tiles
for KNL's L2 (keep v plus two columns resident, chunk ~ 1/3 cache); on
TPU the same insight becomes BlockSpec tiles sized for VMEM with the
reduction over row-tiles accumulated in the revisited output block —
one HBM pass over D per sweep.

The kernel is model-independent; the per-model gap transform (cheap,
elementwise — "negligible evaluation cost" in the paper) is fused by XLA
in the surrounding L2 function (see ``compile/model.py``), which keeps
lam / n / lipschitz-B as *runtime* scalars instead of baking one artifact
per hyperparameter.

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; real-TPU performance is estimated structurally in
DESIGN.md / EXPERIMENTS.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: multiples of the TPU VPU lane/sublane grid (8, 128).
# (d_tile + 2 * d_tile * n_tile / n_steps) floats must fit VMEM; with
# f32 and (512, 256) a D tile is 512 KiB — comfortable against a 16 MiB
# VMEM budget even double-buffered.
D_TILE = 512
N_TILE = 256


def _matvec_kernel(d_ref, w_ref, o_ref, *, nsteps):
    """Grid = (n_tiles, d_tiles); the d (reduction) axis iterates fastest.

    o_ref is revisited across the reduction steps of one column tile and
    used as the accumulator (zeroed on the first step).
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (n_tile, d_tile) @ (d_tile,) -> (n_tile,) partial sums.
    o_ref[...] += jnp.dot(
        d_ref[...].T, w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("d_tile", "n_tile"))
def dtw(d_mat, w, *, d_tile=D_TILE, n_tile=N_TILE):
    """u = D^T w via the tiled Pallas kernel.

    d_mat: (d, n) f32 with d % d_tile == 0 and n % n_tile == 0 (callers
    pad; the rust runtime always feeds full artifact shapes).
    """
    d, n = d_mat.shape
    assert d % d_tile == 0 and n % n_tile == 0, (d, n, d_tile, n_tile)
    nsteps = d // d_tile
    grid = (n // n_tile, nsteps)
    return pl.pallas_call(
        functools.partial(_matvec_kernel, nsteps=nsteps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d_tile, n_tile), lambda i, k: (k, i)),
            pl.BlockSpec((d_tile,), lambda i, k: (k,)),
        ],
        out_specs=pl.BlockSpec((n_tile,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(d_mat, w)


def _axpy_kernel(d_ref, delta_ref, vin_ref, vout_ref):
    """v' = v + D_batch @ delta, tiled over d.  Used by the batched-update
    artifact: applying m coordinate deltas to the shared vector in one
    HBM pass (the dense bulk of task B's v-maintenance)."""
    vout_ref[...] = vin_ref[...] + jnp.dot(
        d_ref[...], delta_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("d_tile",))
def apply_deltas(d_batch, deltas, v, *, d_tile=D_TILE):
    """v' = v + D_batch @ deltas via a row-tiled Pallas kernel.

    d_batch: (d, m); deltas: (m,); v: (d,).
    """
    d, m = d_batch.shape
    assert d % d_tile == 0, (d, d_tile)
    grid = (d // d_tile,)
    return pl.pallas_call(
        _axpy_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d_tile, m), lambda i: (i, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((d_tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((d_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(d_batch, deltas, v)
