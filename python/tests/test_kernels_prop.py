"""Hypothesis property sweeps over the Pallas kernels' shapes and values.

The system prompt contract for L1: hypothesis sweeps the kernel's
shapes/dtypes and asserts allclose against the ref oracle.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import gap, ref

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def tiled_shapes(draw):
    """(d, n, d_tile, n_tile) with d % d_tile == 0, n % n_tile == 0."""
    d_tile = draw(st.sampled_from([64, 128, 256, 512]))
    n_tile = draw(st.sampled_from([64, 128, 256]))
    d = d_tile * draw(st.integers(1, 4))
    n = n_tile * draw(st.integers(1, 3))
    return d, n, d_tile, n_tile


def arrays(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


@given(shapes=tiled_shapes(), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_dtw_any_tiling(shapes, seed):
    d, n, dt, nt = shapes
    rng = np.random.default_rng(seed)
    D = arrays(rng, d, n)
    w = arrays(rng, d)
    got = gap.dtw(D, w, d_tile=dt, n_tile=nt)
    np.testing.assert_allclose(got, D.T @ w, rtol=3e-4, atol=3e-4)


@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.sampled_from(ref.MODELS),
    lam=st.floats(1e-4, 10.0),
    scale=st.floats(1e-3, 100.0),
)
@settings(**SETTINGS)
def test_gaps_fn_value_sweep(seed, m, lam, scale):
    """Gap graph == oracle across magnitudes and hyperparameters."""
    d, n = 256, 128
    rng = np.random.default_rng(seed)
    D = arrays(rng, d, n, scale=scale)
    w = arrays(rng, d)
    a = arrays(rng, n)
    z = model.make_gaps_fn(m, d_tile=128, n_tile=128)(
        D, w, a, jnp.float32(lam), jnp.float32(n), jnp.float32(1.0)
    )[0]
    want = ref.gaps(m, D, w, a, lam, n, 1.0)
    np.testing.assert_allclose(z, want, rtol=5e-3, atol=1e-3 * scale)


@given(seed=st.integers(0, 2**31 - 1), m=st.sampled_from(ref.MODELS))
@settings(**SETTINGS)
def test_cd_delta_stationary_prop(seed, m):
    """Closed-form update is a per-coordinate fixed point, any data."""
    rng = np.random.default_rng(seed)
    n = 64
    col = arrays(rng, 32)
    sq = float(col @ col)
    if sq < 1e-6:
        return
    v, y = arrays(rng, 32), arrays(rng, 32)
    lam = 0.2
    a0 = jnp.float32(rng.uniform(0, 1)) if m == "svm" else jnp.float32(
        rng.standard_normal()
    )
    w = ref.primal_dual_w(m, v, y, lam, n)
    u = float(col @ w)
    delta = float(ref.cd_delta(m, u, a0, sq, lam, n))
    v2 = v + delta * col
    w2 = ref.primal_dual_w(m, v2, y, lam, n)
    u2 = float(col @ w2)
    delta2 = float(ref.cd_delta(m, u2, a0 + delta, sq, lam, n))
    assert abs(delta2) <= 1e-3 * max(1.0, abs(delta)) + 1e-5


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_quantize_roundtrip_prop(seed):
    rng = np.random.default_rng(seed)
    x = arrays(rng, 256, scale=float(rng.uniform(1e-3, 1e3)))
    codes, scales = ref.quantize4(x)
    assert int(jnp.max(codes)) <= 7 and int(jnp.min(codes)) >= -8
    xq = ref.dequantize4(codes, scales)
    err = np.abs(np.asarray(x) - np.asarray(xq)).reshape(-1, ref.QGROUP)
    bound = np.asarray(scales)[:, None] / 2 + 1e-6
    assert (err <= bound).all()
    # pack/unpack is lossless
    np.testing.assert_array_equal(
        np.asarray(ref.unpack4(ref.pack4(codes))), np.asarray(codes)
    )
