"""Kernel-vs-reference correctness: the CORE numerics signal.

The Pallas kernels (interpret=True) and the L2 graphs must agree with
the pure-jnp oracles in ``compile.kernels.ref`` for every model and a
sweep of shapes.  Hypothesis drives the shape/value sweeps in
``test_kernels_prop.py``; this file covers the fixed artifact shapes and
hand-picked edge cases.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import gap, quantized, ref

RNG = np.random.default_rng(1234)


def randf(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# L1: tiled matvec kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "d,n,dt,nt",
    [
        (1024, 256, 512, 256),
        (1024, 256, 1024, 128),
        (2048, 512, 512, 256),
        (512, 128, 128, 128),
        (512, 128, 512, 128),  # single reduction step
    ],
)
def test_dtw_matches_ref(d, n, dt, nt):
    D = randf(d, n)
    w = randf(d)
    got = gap.dtw(D, w, d_tile=dt, n_tile=nt)
    want = D.T @ w
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_dtw_zero_w():
    D = randf(512, 128)
    got = gap.dtw(D, jnp.zeros(512, jnp.float32), d_tile=128, n_tile=128)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(128, np.float32))


def test_dtw_rejects_unaligned():
    with pytest.raises(AssertionError):
        gap.dtw(randf(100, 128), randf(100), d_tile=64, n_tile=128)


def test_apply_deltas_matches_ref():
    d, m = 1024, 64
    D = randf(d, m)
    dl = randf(m)
    v = randf(d)
    got = gap.apply_deltas(D, dl, v)
    want = v + D @ dl
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_apply_deltas_zero_delta_is_identity():
    d, m = 512, 32
    v = randf(d)
    got = gap.apply_deltas(randf(d, m), jnp.zeros(m, jnp.float32), v)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(v))


# ---------------------------------------------------------------------------
# L2: fused gap graphs per model
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", ref.MODELS)
@pytest.mark.parametrize("lam", [1e-3, 0.1, 1.0])
def test_gaps_fn_matches_ref(m, lam):
    d, n = 1024, 256
    D, w, a = randf(d, n), randf(d), randf(n)
    z = model.make_gaps_fn(m)(
        D, w, a, jnp.float32(lam), jnp.float32(n), jnp.float32(2.0)
    )[0]
    want = ref.gaps(m, D, w, a, lam, n, 2.0)
    np.testing.assert_allclose(z, want, rtol=2e-3, atol=1e-4)


@pytest.mark.parametrize("m", ref.MODELS)
def test_gap_transform_nonneg_at_optimum_direction(m):
    """At alpha = 0, w consistent, gaps must be >= 0 (duality)."""
    n = 256
    u = randf(n)
    a = jnp.zeros(n, jnp.float32)
    z = ref.gap_transform(m, u, a, 0.1, n, 1.0)
    assert float(jnp.min(z)) >= -1e-6


def test_lasso_gap_zero_inside_subdifferential():
    """For alpha_i = 0 and |u_i| <= lam the lasso gap must be exactly 0."""
    n = 8
    u = jnp.asarray([0.05, -0.05, 0.0, 0.09, -0.09, 0.02, 0.0, 0.01])
    a = jnp.zeros(n, jnp.float32)
    z = ref.gap_transform("lasso", u, a, 0.1, n, 5.0)
    np.testing.assert_allclose(np.asarray(z), 0.0, atol=1e-7)


def test_svm_gap_zero_at_active_boundary():
    """alpha = 0 and u >= 1/n -> gap 0 (coordinate satisfied)."""
    n = 4
    u = jnp.asarray([0.25, 0.3, 1.0, 0.26], jnp.float32)
    a = jnp.zeros(n, jnp.float32)
    z = ref.gap_transform("svm", u, a, 0.1, n, 1.0)
    np.testing.assert_allclose(np.asarray(z), 0.0, atol=1e-7)


def test_ridge_gap_exact_formula():
    n = 16
    u, a = randf(n), randf(n)
    lam = 0.5
    z = ref.gap_transform("ridge", u, a, lam, n, 0.0)
    want = (np.asarray(u) + lam * np.asarray(a)) ** 2 / (2 * lam)
    np.testing.assert_allclose(np.asarray(z), want, rtol=1e-5)


# ---------------------------------------------------------------------------
# L2: coordinate updates
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", ref.MODELS)
def test_cd_delta_is_stationary(m):
    """After applying the closed-form delta, re-evaluating the update at
    the new point must give delta ~ 0 (fixed point of h-hat)."""
    n = 100
    lam = 0.3
    col = randf(64)
    sq = float(col @ col)
    v = randf(64)
    y = randf(64)
    alpha = jnp.float32(0.7)
    w = ref.primal_dual_w(m, v, y, lam, n)
    u = float(col @ w)
    delta = float(ref.cd_delta(m, u, alpha, sq, lam, n))
    # move v and alpha, recompute
    v2 = v + delta * col
    a2 = alpha + delta
    w2 = ref.primal_dual_w(m, v2, y, lam, n)
    u2 = float(col @ w2)
    delta2 = float(ref.cd_delta(m, u2, a2, sq, lam, n))
    assert abs(delta2) < 1e-4 * max(1.0, abs(delta))


def test_cd_delta_zero_column_is_noop():
    for m in ref.MODELS:
        d = float(
            ref.cd_delta(m, jnp.float32(1.0), jnp.float32(0.5), jnp.float32(0.0), 0.1, 10)
        )
        assert d == 0.0


def test_svm_update_stays_in_box():
    n = 50
    for _ in range(20):
        col = randf(32)
        sq = float(col @ col) + 1e-3
        alpha = float(RNG.uniform(0, 1))
        u = float(RNG.standard_normal() * 10)
        delta = float(ref.cd_delta("svm", u, alpha, sq, 0.01, n))
        assert -1e-6 <= alpha + delta <= 1 + 1e-6


@pytest.mark.parametrize("m", ref.MODELS)
def test_cd_epoch_decreases_objective(m):
    """One sequential epoch over a batch must not increase F(alpha)."""
    d, n, mcols = 256, 64, 32
    D = randf(d, n)
    y = randf(d)
    lam = 0.1
    alpha = randf(n) * 0.1
    v = D @ alpha

    def objective(vv, aa):
        if m in ("lasso", "ridge"):
            fv = 0.5 * float(jnp.sum((vv - y) ** 2))
        else:
            fv = float(jnp.sum(vv * vv)) / (2 * lam * n * n)
        if m == "lasso":
            g = lam * float(jnp.sum(jnp.abs(aa)))
        elif m == "ridge":
            g = 0.5 * lam * float(jnp.sum(aa * aa))
        else:
            g = -float(jnp.sum(aa)) / n
        return fv + g

    if m == "svm":
        alpha = jnp.clip(alpha, 0, 1)
        v = D @ alpha
    before = objective(v, alpha)
    v2, a2, _ = ref.cd_epoch(m, D[:, :mcols], v, alpha[:mcols], y, lam, n)
    full_a2 = alpha.at[:mcols].set(a2)
    after = objective(v2, full_a2)
    assert after <= before + 1e-5 * abs(before)


def test_cd_epoch_keeps_v_consistent():
    """v' must equal D @ alpha' exactly (within fp) after an epoch."""
    d, n = 256, 64
    D = randf(d, n)
    y = randf(d)
    alpha = randf(n) * 0.1
    v = D @ alpha
    v2, a2, _ = ref.cd_epoch("lasso", D, v, alpha, y, 0.1, n)
    np.testing.assert_allclose(v2, D @ a2, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Quantized representation
# ---------------------------------------------------------------------------


def test_quantize4_roundtrip_error_bound():
    """|x - dequant(quant(x))| <= scale/2 = absmax/14 per group."""
    x = randf(1024)
    codes, scales = ref.quantize4(x)
    xq = ref.dequantize4(codes, scales)
    err = np.abs(np.asarray(x) - np.asarray(xq)).reshape(-1, ref.QGROUP)
    bound = np.asarray(scales)[:, None] / 2 + 1e-7
    assert (err <= bound).all()


def test_pack_unpack_roundtrip():
    codes = jnp.asarray(RNG.integers(-8, 8, size=512), jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(ref.unpack4(ref.pack4(codes))), np.asarray(codes)
    )


def test_quantize4_zero_vector():
    codes, scales = ref.quantize4(jnp.zeros(128, jnp.float32))
    assert (np.asarray(codes) == 0).all()
    xq = ref.dequantize4(codes, scales)
    np.testing.assert_array_equal(np.asarray(xq), 0.0)


@pytest.mark.parametrize("m", ref.MODELS)
def test_q4_kernel_matches_q4_ref(m):
    d, n = 1024, 256
    D = randf(d, n)
    w, a = randf(d), randf(n)
    packed_cols, scale_cols = [], []
    for j in range(n):
        c, s = ref.quantize4(D[:, j])
        packed_cols.append(ref.pack4(c))
        scale_cols.append(s)
    packed = jnp.stack(packed_cols, axis=1)
    scales = jnp.stack(scale_cols, axis=1)
    got = model.make_gaps_q4_fn(m)(
        packed, scales, w, a, jnp.float32(0.1), jnp.float32(n), jnp.float32(1.0)
    )[0]
    want = ref.gaps_quantized(m, packed, scales, w, a, 0.1, n, 1.0)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_q4_vs_fp32_gap_close():
    """Quantized gaps approximate fp32 gaps (paper: 4 bits suffice for D)."""
    d, n = 1024, 128
    D = randf(d, n)
    w = randf(d) * 0.1
    a = randf(n) * 0.1
    packed_cols, scale_cols = [], []
    for j in range(n):
        c, s = ref.quantize4(D[:, j])
        packed_cols.append(ref.pack4(c))
        scale_cols.append(s)
    packed = jnp.stack(packed_cols, axis=1)
    scales = jnp.stack(scale_cols, axis=1)
    zq = ref.gaps_quantized("lasso", packed, scales, w, a, 0.1, n, 1.0)
    z = ref.gaps("lasso", D, w, a, 0.1, n, 1.0)
    # (1) inner-product noise is bounded: |u_q - u| <= sum_g |w_g|_1 * s_g/2.
    uq = np.asarray(
        jnp.stack([ref.dequantize4(ref.unpack4(packed[:, j]), scales[:, j]) for j in range(n)], 1).T @ w
    )
    u = np.asarray(D.T @ w)
    w_groups = np.abs(np.asarray(w)).reshape(-1, ref.QGROUP).sum(1)
    bound = (np.asarray(scales).T / 2 * w_groups[None, :]).sum(1) + 1e-4
    assert (np.abs(uq - u) <= bound).all()
    # (2) what HTHC actually needs from 4-bit gaps: the *selection* they
    # induce matches fp32 — top-25% sets overlap strongly.
    k = n // 4
    top = set(np.argsort(-np.asarray(z))[:k])
    topq = set(np.argsort(-np.asarray(zq))[:k])
    assert len(top & topq) >= int(0.8 * k)
