"""ELL sparse gap kernel vs reference (dense densify oracle)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref, sparse_ell

RNG = np.random.default_rng(99)


def random_cols(d, n, max_nnz):
    cols = []
    for _ in range(n):
        nnz = int(RNG.integers(0, max_nnz + 1))
        rows = RNG.choice(d, size=nnz, replace=False)
        cols.append([(int(r), float(RNG.standard_normal())) for r in rows])
    return cols


def densify(cols, d):
    n = len(cols)
    out = np.zeros((d, n), np.float32)
    for j, col in enumerate(cols):
        for r, x in col:
            out[r, j] = x
    return out


def test_ell_matches_dense_matvec():
    d, n, kmax = 512, 256, 64
    cols = random_cols(d, n, kmax)
    idx, val = sparse_ell.to_ell(cols, d, kmax)
    w = jnp.asarray(RNG.standard_normal(d), jnp.float32)
    got = sparse_ell.ell_dtw(idx, val, w)
    want = densify(cols, d).T @ np.asarray(w)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_ell_padding_contributes_nothing():
    d, n, kmax = 128, 256, 64
    cols = [[] for _ in range(n)]  # all padding
    idx, val = sparse_ell.to_ell(cols, d, kmax)
    w = jnp.asarray(RNG.standard_normal(d), jnp.float32)
    got = sparse_ell.ell_dtw(idx, val, w)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(n, np.float32))


@pytest.mark.parametrize("m", ref.MODELS)
def test_gaps_ell_fn_matches_ref(m):
    d, n, kmax = 512, 256, 128
    cols = random_cols(d, n, kmax)
    idx, val = sparse_ell.to_ell(cols, d, kmax)
    w = jnp.asarray(RNG.standard_normal(d), jnp.float32)
    alpha = jnp.asarray(RNG.standard_normal(n), jnp.float32)
    z = model.make_gaps_ell_fn(m)(
        idx, val, w, alpha, jnp.float32(0.2), jnp.float32(n), jnp.float32(1.5)
    )[0]
    dmat = jnp.asarray(densify(cols, d))
    want = ref.gaps(m, dmat, w, alpha, 0.2, n, 1.5)
    np.testing.assert_allclose(z, want, rtol=2e-3, atol=1e-4)


@given(seed=st.integers(0, 2**31 - 1), kt=st.sampled_from([32, 64]))
@settings(max_examples=15, deadline=None)
def test_ell_any_tiling(seed, kt):
    rng = np.random.default_rng(seed)
    d, n, kmax = 256, 256, 128
    cols = []
    for _ in range(n):
        nnz = int(rng.integers(0, 40))
        rows = rng.choice(d, size=nnz, replace=False)
        cols.append([(int(r), float(rng.standard_normal())) for r in rows])
    idx, val = sparse_ell.to_ell(cols, d, kmax)
    w = jnp.asarray(rng.standard_normal(d), jnp.float32)
    got = sparse_ell.ell_dtw(idx, val, w, k_tile=kt, n_tile=128)
    want = densify(cols, d).T @ np.asarray(w)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)
